//! The native training engine: paper Algorithm 1 end-to-end in pure Rust.
//!
//! One [`NativeTrainer::train_step`] is: shared-decoder forward
//! ([`decoder_fwd`]), cross-entropy, full reverse-mode backward
//! ([`decoder_bwd`]) into compact [`super::decoder::ModelGrads`], global-norm gradient
//! clipping, one [`AdamW`] update per parameter tensor with the dense /
//! spectral LR split (driven by `coordinator::schedule::LrPlan`), then
//! Stiefel QR retraction of every U/V factor (paper Eq. 5) every
//! `retract_every` steps. Per-phase wall times are returned so
//! `benches/train_step.rs` can reproduce the paper's Table 2 decomposition
//! at real ranks.
//!
//! Checkpoints use the `.sct` container with the `params/layers/...` layout
//! (see the module docs in [`crate::train`]): the model tensors are exactly
//! what [`crate::serve::SpectralModel::load`] reads, so a trained model
//! serves directly; `opt/{m,v}/...` moments and `opt/t` ride along so a
//! resumed run continues bit-for-bit.

use std::path::Path;
use std::sync::OnceLock;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::checkpoint::format::{read_checkpoint, write_checkpoint, NamedTensor};
use crate::obs::{self, health, prof, Counter, Gauge, Histogram};
use crate::serve::engine::{EngineConfig, SpectralModel};
use crate::spectral::{qr_retract, AdamW, Matrix};
use crate::util::pool;
use crate::util::rng::Rng;

use super::blocks::{cross_entropy, Rope};
use super::decoder::{decoder_bwd, decoder_fwd};

/// Which LR group a parameter tensor belongs to (mirrors
/// `python/compile/optim.py::is_spectral_leaf`: the u/s/v leaves under an
/// mlp block are spectral, everything else is dense).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParamKind {
    Dense,
    Spectral,
}

/// Canonical parameter enumeration: `(name, kind, weight-decay eligible)`
/// in the exact order [`params_mut`] and [`ModelGrads::slices`] yield
/// slices. The names double as the `.sct` tensor names, so this list IS the
/// checkpoint layout contract.
pub fn param_kinds(cfg: &EngineConfig) -> Vec<(String, ParamKind, bool)> {
    use ParamKind::*;
    let mut out = vec![("params/embed".to_string(), Dense, false)];
    for i in 0..cfg.n_layers {
        for w in ["wq", "wk", "wv", "wo"] {
            out.push((format!("params/layers/{i}/attn/{w}"), Dense, true));
        }
        out.push((format!("params/layers/{i}/ln1"), Dense, false));
        out.push((format!("params/layers/{i}/ln2"), Dense, false));
        for nm in ["gate", "up", "down"] {
            for f in ["u", "s", "v"] {
                // s gets no decay (it scales the operator norm); u/v decay is
                // meaningless under retraction — same policy as the JAX side.
                out.push((format!("params/layers/{i}/mlp/{nm}/{f}"), Spectral, false));
            }
        }
    }
    out.push(("params/ln_f".to_string(), Dense, false));
    if !cfg.tied {
        out.push(("params/head".to_string(), Dense, true));
    }
    out
}

/// Mutable flat views of every parameter tensor, in [`param_kinds`] order.
fn params_mut(model: &mut SpectralModel) -> Vec<&mut [f32]> {
    let mut out: Vec<&mut [f32]> = vec![&mut model.embed.data];
    for l in &mut model.layers {
        out.push(&mut l.wq.data);
        out.push(&mut l.wk.data);
        out.push(&mut l.wv.data);
        out.push(&mut l.wo.data);
        out.push(&mut l.ln1);
        out.push(&mut l.ln2);
        for sl in [&mut l.gate, &mut l.up, &mut l.down] {
            out.push(&mut sl.u.data);
            out.push(&mut sl.s);
            out.push(&mut sl.v.data);
        }
    }
    out.push(&mut model.ln_f);
    if let Some(h) = &mut model.head {
        out.push(&mut h.data);
    }
    out
}

/// Training-run hyperparameters (the model geometry rides in `model`).
#[derive(Debug, Clone, Copy)]
pub struct NativeTrainConfig {
    pub model: EngineConfig,
    pub batch: usize,
    /// Input sequence length T; one packed window is T+1 tokens.
    pub seq_len: usize,
    /// Global gradient-norm clip (0 disables).
    pub grad_clip: f32,
    /// QR-retract every N optimizer steps (paper default: every step).
    pub retract_every: usize,
    pub weight_decay: f32,
}

impl Default for NativeTrainConfig {
    fn default() -> NativeTrainConfig {
        NativeTrainConfig {
            model: EngineConfig::default(),
            batch: 8,
            seq_len: 64,
            grad_clip: 1.0,
            retract_every: 1,
            weight_decay: 0.0,
        }
    }
}

/// `sct_train_*` series published by every [`NativeTrainer::train_step`]:
/// step/clip counters, loss and grad-norm gauges, and one latency histogram
/// per phase of Table 2's `[forward, backward, optimizer, retract]` split.
struct TrainMetrics {
    steps: Counter,
    clips: Counter,
    loss: Gauge,
    grad_norm: Gauge,
    phase_ms: [Histogram; 4],
}

fn train_metrics() -> &'static TrainMetrics {
    static METRICS: OnceLock<TrainMetrics> = OnceLock::new();
    METRICS.get_or_init(|| {
        let r = obs::registry();
        let phase = |p: &str| {
            r.histogram_with(
                "sct_train_phase_ms",
                &[("phase", p)],
                "Per-phase train_step wall time, milliseconds",
            )
        };
        TrainMetrics {
            steps: r.counter("sct_train_steps_total", "Optimizer steps taken"),
            clips: r.counter(
                "sct_train_clip_total",
                "Steps where the global grad norm exceeded grad_clip and was rescaled",
            ),
            loss: r.gauge("sct_train_loss", "Training cross-entropy of the latest step"),
            grad_norm: r.gauge(
                "sct_train_grad_norm",
                "Pre-clip global gradient norm of the latest step (0 when clipping is off)",
            ),
            phase_ms: [phase("forward"), phase("backward"), phase("optimizer"), phase("retract")],
        }
    })
}

/// Model + optimizer state + RoPE tables: everything one training run owns.
pub struct NativeTrainer {
    pub cfg: NativeTrainConfig,
    pub model: SpectralModel,
    rope: Rope,
    opts: Vec<AdamW>,
    kinds: Vec<(String, ParamKind, bool)>,
    /// Optimizer steps taken (also the checkpoint step).
    pub step: u64,
    /// Consult the armed [`health`] watchdog inside [`train_step`]
    /// (off by default; the run driver opts in per run so a watchdog armed
    /// elsewhere in the process never perturbs an unrelated trainer).
    pub watchdog: bool,
    /// The watchdog verdict of the most recent step ([`health::Verdict::Ok`]
    /// when the watchdog is off) — the run driver reads this to halt.
    pub last_verdict: health::Verdict,
}

impl NativeTrainer {
    pub fn new(cfg: NativeTrainConfig, seed: u64) -> NativeTrainer {
        let model = SpectralModel::init(cfg.model, seed);
        NativeTrainer::from_model(cfg, model)
    }

    /// Wrap an existing model (checkpoint restore) with fresh optimizer state.
    pub fn from_model(mut cfg: NativeTrainConfig, model: SpectralModel) -> NativeTrainer {
        cfg.model = model.cfg;
        cfg.retract_every = cfg.retract_every.max(1);
        assert!(
            cfg.seq_len >= 1 && cfg.seq_len <= cfg.model.max_seq,
            "seq_len {} must fit the RoPE table (max_seq {})",
            cfg.seq_len,
            cfg.model.max_seq
        );
        assert!(cfg.batch >= 1, "need at least one sequence per batch");
        let rope = Rope::new(cfg.model.max_seq, cfg.model.head_dim());
        let kinds = param_kinds(&cfg.model);
        let mut model = model;
        let lens: Vec<usize> = params_mut(&mut model).iter().map(|s| s.len()).collect();
        assert_eq!(lens.len(), kinds.len(), "param enumeration out of sync");
        let opts = lens.into_iter().map(|n| AdamW::new(n, 0.0)).collect();
        NativeTrainer {
            cfg,
            model,
            rope,
            opts,
            kinds,
            step: 0,
            watchdog: false,
            last_verdict: health::Verdict::Ok,
        }
    }

    /// Unpack a packed `batch x (seq_len + 1)` window (the
    /// `Dataset::next_batch` wire format: inputs and shifted targets share
    /// one buffer) into `(inputs, targets)` of `batch * seq_len` each.
    fn split_window(&self, tokens: &[i32]) -> (Vec<i32>, Vec<i32>) {
        let (b, t) = (self.cfg.batch, self.cfg.seq_len);
        assert_eq!(tokens.len(), b * (t + 1), "tokens must be batch x (seq_len + 1)");
        let mut inputs = Vec::with_capacity(b * t);
        let mut targets = Vec::with_capacity(b * t);
        for r in 0..b {
            let w = &tokens[r * (t + 1)..(r + 1) * (t + 1)];
            inputs.extend_from_slice(&w[..t]);
            targets.extend_from_slice(&w[1..]);
        }
        (inputs, targets)
    }

    /// One full training step on a packed `batch x (seq_len + 1)` window.
    /// Returns the loss and the per-phase seconds
    /// `[forward, backward, optimizer, retraction]` — Table 2's split.
    pub fn train_step(
        &mut self,
        tokens: &[i32],
        lr_dense: f32,
        lr_spectral: f32,
    ) -> (f32, [f64; 4]) {
        let (b, t) = (self.cfg.batch, self.cfg.seq_len);
        let (inputs, targets) = self.split_window(tokens);

        // Profiler root covering exactly the four timed phases below, so the
        // phase tree's train_step wall agrees with the returned split.
        let _prof_step = prof::scope("train_step");

        let t0 = Instant::now();
        let (cache, loss, dlogits) = {
            let _p = prof::scope("forward");
            let (logits, cache) = decoder_fwd(&self.model, &self.rope, &inputs, b, t);
            let (loss, dlogits) = cross_entropy(&logits, &targets);
            (cache, loss, dlogits)
        };
        let t_fwd = t0.elapsed().as_secs_f64();

        // Watchdog (off by default): fold every check of this step into one
        // policy-resolved verdict; `skip`/`halt` drop the update below so an
        // anomalous step can never poison the factors or the Adam moments.
        let mut verdict = if self.watchdog {
            health::check_loss(self.step + 1, loss)
        } else {
            health::Verdict::Ok
        };

        let t1 = Instant::now();
        let mut grads = {
            let _p = prof::scope("backward");
            decoder_bwd(&self.model, &self.rope, &inputs, b, t, &cache, &dlogits)
        };
        let t_bwd = t1.elapsed().as_secs_f64();

        let m = train_metrics();
        let t2 = Instant::now();
        {
            let _p = prof::scope("optimizer");
            if self.cfg.grad_clip > 0.0 || self.watchdog {
                let norm = grads.global_norm();
                m.grad_norm.set(norm as f64);
                if self.watchdog {
                    verdict = verdict.max(health::check_grad_norm(self.step + 1, norm as f64));
                }
                if self.cfg.grad_clip > 0.0 && norm > self.cfg.grad_clip {
                    grads.scale(self.cfg.grad_clip / norm);
                    m.clips.inc();
                }
            }
            if verdict.skips_update() {
                health::note_skipped_step();
            } else {
                let params = params_mut(&mut self.model);
                let gs = grads.slices();
                debug_assert_eq!(params.len(), gs.len());
                for (i, (p, g)) in params.into_iter().zip(gs).enumerate() {
                    let (_, kind, decays) = &self.kinds[i];
                    let opt = &mut self.opts[i];
                    opt.lr = match kind {
                        ParamKind::Spectral => lr_spectral,
                        ParamKind::Dense => lr_dense,
                    };
                    opt.weight_decay = if *decays { self.cfg.weight_decay } else { 0.0 };
                    opt.step(p, g);
                }
            }
        }
        let t_opt = t2.elapsed().as_secs_f64();

        let t3 = Instant::now();
        self.step += 1;
        if !verdict.skips_update() && self.step % self.cfg.retract_every as u64 == 0 {
            let _p = prof::scope("retract");
            retract_model(&mut self.model);
        }
        let t_retract = t3.elapsed().as_secs_f64();

        // Post-step spectrum scan: NaN leaked into s, or a collapsed
        // (all-zero) spectrum. The s vectors are k floats per triple, so
        // this stays O(rank) per layer.
        if self.watchdog {
            for (li, l) in self.model.layers.iter().enumerate() {
                for (nm, sl) in [("gate", &l.gate), ("up", &l.up), ("down", &l.down)] {
                    verdict = verdict.max(health::check_spectrum(self.step, li, nm, &sl.s));
                }
            }
        }
        self.last_verdict = verdict;

        m.steps.inc();
        m.loss.set(loss as f64);
        for (h, secs) in m.phase_ms.iter().zip([t_fwd, t_bwd, t_opt, t_retract]) {
            h.record(secs * 1e3);
        }

        (loss, [t_fwd, t_bwd, t_opt, t_retract])
    }

    /// Cross-entropy on a held-out packed window, no state change.
    pub fn eval_loss(&self, tokens: &[i32]) -> f32 {
        let (b, t) = (self.cfg.batch, self.cfg.seq_len);
        let (inputs, targets) = self.split_window(tokens);
        let (logits, _) = decoder_fwd(&self.model, &self.rope, &inputs, b, t);
        cross_entropy(&logits, &targets).0
    }

    // -- rank transitions (the `rank` subsystem) ----------------------------

    /// Current rank of every layer's MLP triples.
    pub fn layer_ranks(&self) -> Vec<usize> {
        self.model.layer_ranks()
    }

    /// Resize one layer's MLP triples (gate/up/down share a rank) to
    /// `new_k`, resizing the matching AdamW moment tensors in lockstep.
    ///
    /// Grow appends orthonormal-complement columns with **zero** singular
    /// values, so the forward — and therefore the loss — is unchanged
    /// across the transition (exact continuation; the new capacity is
    /// picked up by the optimizer through the `s` gradients). Shrink drops
    /// the smallest-|s| directions, truncated-SVD style, keeping the
    /// surviving moments aligned with their parameters. The appended
    /// columns are built by the same CGS2 construction as the QR
    /// retraction, so the 2e-6 orthonormality budget holds without a full
    /// re-retraction; a degenerate draw falls back to retracting the
    /// triple (which perturbs the forward within float noise).
    pub fn set_layer_rank(&mut self, layer: usize, new_k: usize, rng: &mut Rng) -> Result<()> {
        use crate::rank::resize::{resize_triple, RankResize};
        anyhow::ensure!(
            layer < self.model.layers.len(),
            "layer {layer} out of range (model has {})",
            self.model.layers.len()
        );
        let c = self.cfg.model;
        anyhow::ensure!(
            new_k >= 1 && new_k <= c.d_model.min(c.d_ffn),
            "rank {new_k} out of range for ({}, {})",
            c.d_model,
            c.d_ffn
        );
        let lw = &mut self.model.layers[layer];
        for (nm, sl) in [("gate", &mut lw.gate), ("up", &mut lw.up), ("down", &mut lw.down)] {
            let old_k = sl.k();
            let (rows_u, rows_v) = (sl.m(), sl.n());
            let change = resize_triple(sl, new_k, rng);
            if matches!(change, RankResize::Unchanged) {
                continue;
            }
            if sl.ortho_error() > 2e-6 {
                sl.retract(); // safety net; unreachable for Gaussian draws
            }
            for (f, rows) in [("u", rows_u), ("s", 1usize), ("v", rows_v)] {
                let name = format!("params/layers/{layer}/mlp/{nm}/{f}");
                let idx = self
                    .kinds
                    .iter()
                    .position(|(n, _, _)| *n == name)
                    .expect("param enumeration must contain every spectral tensor");
                match &change {
                    RankResize::Grown { .. } => self.opts[idx].grow_cols(rows, old_k, new_k),
                    RankResize::Shrunk { kept, .. } => {
                        self.opts[idx].select_cols(rows, old_k, kept)
                    }
                    RankResize::Unchanged => unreachable!("filtered above"),
                }
            }
        }
        // cfg.rank records the max layer rank so the checkpoint header (and
        // EngineConfig::validate) stay coherent under heterogeneous ranks.
        let max_k = self.model.layer_ranks().into_iter().max().unwrap_or(new_k);
        self.model.cfg.rank = max_k;
        self.cfg.model.rank = max_k;
        Ok(())
    }

    /// Worst factor orthonormality error across every spectral triple —
    /// the paper's `max |U^T U - I|` budget of 2e-6.
    pub fn ortho_error(&self) -> f32 {
        self.model
            .layers
            .iter()
            .flat_map(|l| [&l.gate, &l.up, &l.down])
            .map(|sl| sl.ortho_error())
            .fold(0.0, f32::max)
    }

    // -- checkpointing ------------------------------------------------------

    /// Model tensors (the `params/layers/...` layout `serve` loads directly)
    /// plus the AdamW moments and step so training resumes exactly.
    pub fn checkpoint_tensors(&self) -> Vec<NamedTensor> {
        let mut tensors = self.model.to_tensors();
        for ((name, _, _), opt) in self.kinds.iter().zip(&self.opts) {
            let (m, v) = opt.moments();
            tensors.push(NamedTensor::f32(&format!("opt/m/{name}"), vec![m.len()], m));
            tensors.push(NamedTensor::f32(&format!("opt/v/{name}"), vec![v.len()], v));
        }
        tensors.push(NamedTensor::i32("opt/t", vec![1], &[self.step as i32]));
        tensors
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        write_checkpoint(path, self.step, &self.checkpoint_tensors())
    }

    /// Restore a training run. Model geometry comes from the checkpoint (it
    /// overrides `cfg.model`); optimizer moments are restored when present
    /// (a serve-only checkpoint trains on with fresh moments).
    pub fn load(path: &Path, cfg: NativeTrainConfig) -> Result<NativeTrainer> {
        let (step, tensors) = read_checkpoint(path)?;
        let model = SpectralModel::from_tensors(&tensors)
            .with_context(|| format!("loading model from {}", path.display()))?;
        let mut trainer = NativeTrainer::from_model(cfg, model);
        trainer.step = step;
        if tensors.iter().any(|t| t.name == "opt/t") {
            let find = |name: &str| -> Result<Vec<f32>> {
                tensors
                    .iter()
                    .find(|t| t.name == name)
                    .with_context(|| format!("checkpoint missing optimizer tensor {name:?}"))?
                    .as_f32()
            };
            let t_opt = tensors
                .iter()
                .find(|t| t.name == "opt/t")
                .expect("checked above")
                .as_i32()?[0] as u64;
            for ((name, _, _), opt) in trainer.kinds.iter().zip(trainer.opts.iter_mut()) {
                let m = find(&format!("opt/m/{name}"))?;
                let v = find(&format!("opt/v/{name}"))?;
                opt.restore(m, v, t_opt);
            }
        }
        Ok(trainer)
    }
}

/// QR-retract every spectral factor of the model, fanned out across the
/// worker pool: the 6 factors per layer (gate/up/down × U/V) are mutually
/// independent, so each worker retracts a contiguous share of the flat
/// factor list. Each factor runs the same serial CGS2 kernel
/// ([`qr_retract`]) the single-threaded path runs, so the retracted model
/// is bit-identical at any thread count.
fn retract_model(model: &mut SpectralModel) {
    let mut factors: Vec<&mut Matrix> = Vec::with_capacity(model.layers.len() * 6);
    for l in &mut model.layers {
        for sl in [&mut l.gate, &mut l.up, &mut l.down] {
            factors.push(&mut sl.u);
            factors.push(&mut sl.v);
        }
    }
    if pool::threads() <= 1 {
        for f in factors {
            *f = qr_retract(f);
        }
        return;
    }
    let chunk = pool::chunk_len(factors.len());
    let prof_ctx = prof::fork_ctx();
    std::thread::scope(|s| {
        for group in factors.chunks_mut(chunk) {
            let prof_ctx = &prof_ctx;
            s.spawn(move || {
                let _prof = prof::attach(prof_ctx);
                for f in group.iter_mut() {
                    **f = qr_retract(&**f);
                }
            });
        }
    });
}

/// Analytic MLP compression factor vs a dense model of the same geometry
/// (the Table 3 column) — native twin of `Trainer::mlp_compression`.
pub fn mlp_compression(cfg: &EngineConfig) -> f64 {
    let dense = (3 * cfg.d_model * cfg.d_ffn) as f64;
    let spectral = (3 * cfg.rank * (cfg.d_model + cfg.d_ffn + 1)) as f64;
    dense / spectral
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> NativeTrainConfig {
        NativeTrainConfig {
            model: EngineConfig {
                vocab: 32,
                d_model: 16,
                n_layers: 2,
                n_heads: 2,
                d_ffn: 24,
                rank: 3,
                max_seq: 16,
                tied: true,
            },
            batch: 2,
            seq_len: 8,
            grad_clip: 1.0,
            retract_every: 1,
            weight_decay: 0.0,
        }
    }

    /// A learnable stream: tokens cycle 0..8, so next-token prediction is
    /// fully determined and the loss floor is ~0.
    fn cyclic_batch(cfg: &NativeTrainConfig, offset: usize) -> Vec<i32> {
        let w = cfg.seq_len + 1;
        (0..cfg.batch * w)
            .map(|i| {
                let (row, col) = (i / w, i % w);
                ((offset + row * 3 + col) % 8) as i32
            })
            .collect()
    }

    #[test]
    fn param_enumeration_matches_grad_slices() {
        let cfg = tiny_cfg();
        let mut trainer = NativeTrainer::new(cfg, 0);
        let batch = cyclic_batch(&cfg, 0);
        // grads via one real backward
        let (b, t) = (cfg.batch, cfg.seq_len);
        let mut inputs = Vec::new();
        for r in 0..b {
            inputs.extend_from_slice(&batch[r * (t + 1)..r * (t + 1) + t]);
        }
        let (logits, cache) = decoder_fwd(&trainer.model, &trainer.rope, &inputs, b, t);
        let targets: Vec<i32> = inputs.clone();
        let (_, dl) = cross_entropy(&logits, &targets);
        let grads = decoder_bwd(&trainer.model, &trainer.rope, &inputs, b, t, &cache, &dl);
        let gs = grads.slices();
        let names = param_kinds(&trainer.model.cfg);
        let ps = params_mut(&mut trainer.model);
        assert_eq!(ps.len(), gs.len());
        assert_eq!(ps.len(), names.len());
        for (i, (p, g)) in ps.iter().zip(&gs).enumerate() {
            assert_eq!(p.len(), g.len(), "length mismatch at {:?}", names[i].0);
        }
        // untied adds exactly one more tensor
        let untied = EngineConfig { tied: false, ..trainer.model.cfg };
        assert_eq!(param_kinds(&untied).len(), names.len() + 1);
    }

    #[test]
    fn loss_decreases_on_learnable_stream() {
        let cfg = tiny_cfg();
        let mut trainer = NativeTrainer::new(cfg, 1);
        let mut first = None;
        let mut last = 0.0f32;
        for step in 0..40 {
            let (loss, _) = trainer.train_step(&cyclic_batch(&cfg, step), 5e-3, 5e-3);
            if first.is_none() {
                first = Some(loss);
            }
            last = loss;
        }
        let first = first.unwrap();
        assert!(
            last < first * 0.7,
            "loss must fall on a deterministic stream: {first} -> {last}"
        );
        assert!(last.is_finite());
    }

    #[test]
    fn factors_stay_on_the_stiefel_manifold_after_50_steps() {
        // The paper's acceptance budget: max |U^T U - I| <= 2e-6 with
        // retraction every step.
        let cfg = tiny_cfg();
        let mut trainer = NativeTrainer::new(cfg, 2);
        for step in 0..50 {
            trainer.train_step(&cyclic_batch(&cfg, step), 3e-3, 3e-3);
        }
        let err = trainer.ortho_error();
        assert!(err <= 2e-6, "orthonormality drift {err} exceeds the 2e-6 budget");
        assert_eq!(trainer.step, 50);
    }

    #[test]
    fn retract_every_defers_retraction() {
        let mut cfg = tiny_cfg();
        cfg.retract_every = 1000; // never, within this test
        let mut trainer = NativeTrainer::new(cfg, 3);
        for step in 0..10 {
            trainer.train_step(&cyclic_batch(&cfg, step), 5e-3, 5e-3);
        }
        let drifted = trainer.ortho_error();
        assert!(drifted > 2e-6, "without retraction the factors must drift (got {drifted})");
        // a manual retraction brings them back
        for l in &mut trainer.model.layers {
            l.gate.retract();
            l.up.retract();
            l.down.retract();
        }
        assert!(trainer.ortho_error() <= 2e-6);
    }

    #[test]
    fn checkpoint_roundtrip_resumes_identically() {
        let cfg = tiny_cfg();
        let dir = std::env::temp_dir().join(format!("sct_native_ckpt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("train.sct");

        let mut a = NativeTrainer::new(cfg, 4);
        for step in 0..5 {
            a.train_step(&cyclic_batch(&cfg, step), 2e-3, 2e-3);
        }
        a.save(&path).unwrap();
        let mut b = NativeTrainer::load(&path, cfg).unwrap();
        assert_eq!(b.step, 5);
        // identical next step: same loss, same params after the update
        let batch = cyclic_batch(&cfg, 99);
        let (la, _) = a.train_step(&batch, 2e-3, 2e-3);
        let (lb, _) = b.train_step(&batch, 2e-3, 2e-3);
        assert_eq!(la, lb, "restored run must continue bit-for-bit");
        assert_eq!(a.model.embed.data, b.model.embed.data);
        assert_eq!(a.model.layers[0].gate.u.data, b.model.layers[0].gate.u.data);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn eval_loss_is_pure() {
        let cfg = tiny_cfg();
        let trainer = NativeTrainer::new(cfg, 5);
        let batch = cyclic_batch(&cfg, 0);
        let a = trainer.eval_loss(&batch);
        let b = trainer.eval_loss(&batch);
        assert_eq!(a, b);
        assert!(a > 0.0 && a.is_finite());
    }

    #[test]
    fn grad_clip_bounds_the_update() {
        // With an absurdly small clip the first update must be tiny even
        // though AdamW normalizes: the clip acts on the raw gradient, the
        // optimizer still moves ~lr per coordinate — so instead check the
        // clip math directly through ModelGrads in decoder tests, and here
        // only that training with clip stays finite at a hot LR.
        let mut cfg = tiny_cfg();
        cfg.grad_clip = 0.5;
        let mut trainer = NativeTrainer::new(cfg, 6);
        for step in 0..10 {
            let (loss, _) = trainer.train_step(&cyclic_batch(&cfg, step), 5e-2, 5e-2);
            assert!(loss.is_finite(), "clipped training must not diverge to NaN");
        }
    }

    #[test]
    fn grow_is_loss_continuous_and_training_resumes() {
        let cfg = tiny_cfg();
        let mut trainer = NativeTrainer::new(cfg, 8);
        let mut rng = Rng::new(123);
        for step in 0..12 {
            trainer.train_step(&cyclic_batch(&cfg, step), 3e-3, 3e-3);
        }
        let probe = cyclic_batch(&cfg, 1000);
        let before = trainer.eval_loss(&probe);
        trainer.set_layer_rank(0, 6, &mut rng).unwrap();
        trainer.set_layer_rank(1, 5, &mut rng).unwrap();
        assert_eq!(trainer.layer_ranks(), vec![6, 5]);
        assert_eq!(trainer.cfg.model.rank, 6, "cfg.rank tracks the max layer rank");
        let after = trainer.eval_loss(&probe);
        assert!(
            (before - after).abs() <= 1e-5,
            "grow must be loss-continuous: {before} vs {after}"
        );
        assert!(trainer.ortho_error() <= 2e-6, "ortho {}", trainer.ortho_error());
        // training continues through the grown factors and keeps improving
        let mut last = f32::INFINITY;
        for step in 0..40 {
            let (l, _) = trainer.train_step(&cyclic_batch(&cfg, step), 3e-3, 3e-3);
            assert!(l.is_finite());
            last = l;
        }
        assert!(last < before, "loss must keep falling after the grow: {before} -> {last}");
    }

    #[test]
    fn shrink_keeps_training_aligned_and_on_manifold() {
        let cfg = tiny_cfg();
        let mut trainer = NativeTrainer::new(cfg, 9);
        let mut rng = Rng::new(5);
        trainer.set_layer_rank(0, 8, &mut rng).unwrap();
        for step in 0..10 {
            trainer.train_step(&cyclic_batch(&cfg, step), 3e-3, 3e-3);
        }
        trainer.set_layer_rank(0, 2, &mut rng).unwrap();
        trainer.set_layer_rank(1, 2, &mut rng).unwrap();
        assert_eq!(trainer.layer_ranks(), vec![2, 2]);
        assert!(trainer.ortho_error() <= 2e-6);
        // every subsequent step exercises the param/grad/moment alignment
        // asserts inside AdamW::step
        for step in 0..10 {
            let (l, _) = trainer.train_step(&cyclic_batch(&cfg, step), 3e-3, 3e-3);
            assert!(l.is_finite(), "training after a shrink must stay finite");
        }
    }

    #[test]
    fn heterogeneous_checkpoint_resumes_bit_for_bit() {
        let cfg = tiny_cfg();
        let dir = std::env::temp_dir().join(format!("sct_rank_ckpt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("hetero_train.sct");

        let mut a = NativeTrainer::new(cfg, 10);
        let mut rng = Rng::new(77);
        for step in 0..4 {
            a.train_step(&cyclic_batch(&cfg, step), 2e-3, 2e-3);
        }
        a.set_layer_rank(0, 7, &mut rng).unwrap();
        for step in 4..8 {
            a.train_step(&cyclic_batch(&cfg, step), 2e-3, 2e-3);
        }
        a.save(&path).unwrap();
        // `cfg` still describes the pre-grow geometry; the checkpoint's
        // model/meta (incl. per-layer ranks) must win on restore.
        let mut b = NativeTrainer::load(&path, cfg).unwrap();
        assert_eq!(b.layer_ranks(), vec![7, 3]);
        assert_eq!(b.step, 8);
        let batch = cyclic_batch(&cfg, 99);
        let (la, _) = a.train_step(&batch, 2e-3, 2e-3);
        let (lb, _) = b.train_step(&batch, 2e-3, 2e-3);
        assert_eq!(la, lb, "heterogeneous-rank resume must continue bit-for-bit");
        assert_eq!(a.model.layers[0].gate.u.data, b.model.layers[0].gate.u.data);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn set_layer_rank_rejects_out_of_range() {
        let cfg = tiny_cfg();
        let mut trainer = NativeTrainer::new(cfg, 11);
        let mut rng = Rng::new(1);
        assert!(trainer.set_layer_rank(5, 4, &mut rng).is_err(), "bad layer index");
        // min(d_model=16, d_ffn=24) = 16 caps the rank
        assert!(trainer.set_layer_rank(0, 17, &mut rng).is_err(), "rank above min dim");
        assert!(trainer.set_layer_rank(0, 0, &mut rng).is_err(), "rank zero");
        // no-op resize leaves everything untouched
        let before = trainer.model.layers[0].gate.u.data.clone();
        trainer.set_layer_rank(0, 3, &mut rng).unwrap();
        assert_eq!(trainer.model.layers[0].gate.u.data, before);
    }

    #[test]
    fn mlp_compression_matches_table_formula() {
        let cfg = EngineConfig { d_model: 8192, d_ffn: 28672, rank: 32, ..EngineConfig::default() };
        let c = mlp_compression(&cfg);
        // 3*8192*28672 / (3*32*(8192+28672+1)) ~ 199x
        assert!((c - 199.0).abs() < 1.0, "compression {c}");
    }

    #[test]
    fn watchdog_skip_leaves_model_untouched() {
        let _g = health::test_guard();
        // A grad-norm ceiling of ~0 makes the very first step anomalous.
        health::configure(health::WatchdogConfig {
            policy: health::Policy::Skip,
            grad_max: 1e-12,
            ..Default::default()
        });
        let cfg = tiny_cfg();
        let mut trainer = NativeTrainer::new(cfg, 12);
        trainer.watchdog = true;
        let embed_before = trainer.model.embed.data.clone();
        let s_before = trainer.model.layers[0].gate.s.clone();
        let u_before = trainer.model.layers[0].gate.u.data.clone();
        let (loss, _) = trainer.train_step(&cyclic_batch(&cfg, 0), 5e-3, 5e-3);
        assert!(loss.is_finite());
        assert_eq!(trainer.last_verdict, health::Verdict::Skip);
        assert_eq!(trainer.step, 1, "a skipped step still advances the step counter");
        assert_eq!(trainer.model.embed.data, embed_before, "skip must not touch dense params");
        assert_eq!(trainer.model.layers[0].gate.s, s_before, "skip must not touch s");
        assert_eq!(trainer.model.layers[0].gate.u.data, u_before, "skip must not retract U");
        health::disable();
    }

    #[test]
    fn watchdog_halt_verdict_surfaces_without_applying_the_update() {
        let _g = health::test_guard();
        health::configure(health::WatchdogConfig {
            policy: health::Policy::Halt,
            grad_max: 1e-12,
            ..Default::default()
        });
        let cfg = tiny_cfg();
        let mut trainer = NativeTrainer::new(cfg, 13);
        trainer.watchdog = true;
        let s_before = trainer.model.layers[0].gate.s.clone();
        let (_, _) = trainer.train_step(&cyclic_batch(&cfg, 0), 5e-3, 5e-3);
        assert!(trainer.last_verdict.halts());
        assert_eq!(trainer.model.layers[0].gate.s, s_before, "halt must not apply the update");
        health::disable();

        // With the watchdog disarmed (the default), an armed-elsewhere
        // policy is irrelevant: verdict stays Ok.
        let mut plain = NativeTrainer::new(cfg, 13);
        plain.train_step(&cyclic_batch(&cfg, 0), 5e-3, 5e-3);
        assert_eq!(plain.last_verdict, health::Verdict::Ok);
    }

    #[test]
    fn spectral_lr_group_is_honored() {
        // With lr_dense = 0 only the spectral factors may move.
        let cfg = tiny_cfg();
        let mut trainer = NativeTrainer::new(cfg, 7);
        let wq_before = trainer.model.layers[0].wq.data.clone();
        let s_before = trainer.model.layers[0].gate.s.clone();
        trainer.train_step(&cyclic_batch(&cfg, 0), 0.0, 1e-2);
        assert_eq!(trainer.model.layers[0].wq.data, wq_before, "dense params frozen at lr 0");
        assert_ne!(trainer.model.layers[0].gate.s, s_before, "spectral params must move");
    }
}
