//! # SCT — Spectral Compact Training
//!
//! Reproduction of "Spectral Compact Training: Pre-Training Large Language
//! Models via Permanent Truncated SVD and Stiefel QR Retraction"
//! (Kohlberger, 2026) as a three-layer Rust + JAX + Pallas stack.
//!
//! Every weight matrix is stored permanently as its rank-`k` truncated SVD
//! `W = U·diag(s)·Vᵀ`; the dense matrix is never materialized. Gradients flow
//! through the compact factors, AdamW updates them, and `U`, `V` are
//! retracted to the Stiefel manifold via QR after every optimizer step.
//!
//! Layer map:
//! * [`runtime`] — PJRT client wrapper: loads AOT-compiled HLO artifacts
//!   (produced once by `python/compile/aot.py`) and executes them with
//!   device-resident state. Python never runs at training time. Execution
//!   requires the `pjrt` feature; the manifest/dtype layer is always built.
//! * [`coordinator`] — the training orchestrator: config, LR schedules,
//!   trainer loop, rank-sweep / fine-tune drivers (drivers need `pjrt`).
//! * [`serve`] — the pure-Rust spectral inference engine: KV-cached
//!   incremental decoding, continuous-batching schedulers with chunked
//!   prefill + stop sequences, sharded across N engine-clone workers behind
//!   a load-aware gateway (`--workers`), and a std-net HTTP server speaking
//!   a typed versioned wire API (`serve::api`: request/response/error
//!   envelope types) with keep-alive + SSE token streaming — the deployment
//!   side of "never materialized", no PJRT required.
//! * [`train`] — the pure-Rust **training** engine: the shared decoder
//!   blocks (one forward implementation for serve and train), full
//!   reverse-mode backward into compact factor gradients, per-tensor AdamW
//!   with the dense/spectral LR split, gradient clipping, and Stiefel QR
//!   retraction every step — paper Algorithm 1 end-to-end with no PJRT,
//!   checkpointing to the same `.sct` layout `serve` loads.
//! * [`rank`] — the adaptive-rank subsystem: loss-continuous grow/shrink of
//!   spectral factors during native training (orthonormal-complement column
//!   appends with zero singular values; smallest-|s| drops), scheduled and
//!   tail-energy-driven policies, and per-layer spectral-energy monitoring
//!   surfaced through `metrics` — live rank transitions with no recompiled
//!   artifact, heterogeneous per-layer ranks end to end.
//! * [`spectral`] — pure-Rust spectral linear algebra substrate (matrix ops,
//!   Householder QR, Jacobi SVD, AdamW, a native SpectralLinear layer) used
//!   for baselines, property tests, true-shape 70B phase benchmarks, and
//!   the train/serve forward paths. Its hot loops are
//!   [`spectral::microkernel`]'s cache-blocked GEBP tiles and fused
//!   dot/axpy kernels: AVX2+FMA paths behind runtime feature detection
//!   with bit-identical fused-scalar fallbacks, packed k-panels, and two
//!   canonical accumulation orders that every matmul, attention row and
//!   CGS2 update realizes — the SIMD dispatch is a speed knob, never a
//!   numerics fork.
//! * [`memmodel`] — the analytic training-memory model that regenerates the
//!   paper's Table 1 / Table 2 / Figure 1 numbers exactly.
//! * [`data`] — tokenizer, synthetic instruction corpus (Alpaca substitute),
//!   packing, batching, async prefetch.
//! * [`metrics`] — loss/PPL tracking with the paper's window-50 smoothing,
//!   CSV/JSON export and ASCII plots for the figures.
//! * [`obs`] — the runtime observability layer: a process-global registry of
//!   lock-free counters/gauges/log-bucketed histograms with Prometheus text
//!   exposition (`GET /metrics`, `sct train --metrics-out` JSONL), per-request
//!   span tracing (`traces.jsonl`, request ids in SSE frames and
//!   `/v1/generate` responses, gateway→worker→prefill→decode span trees
//!   linked by parent ids), the leveled `SCT_LOG`/`--log-level` logger
//!   behind `sct_info!`-family macros, and the `obs::prof` performance
//!   profiler (`--profile-out`, `GET /v1/profile`): a hierarchical
//!   phase/kernel tree with per-kernel FLOP + byte work models, roofline
//!   accounting against a calibrated machine peak, and flamegraph `.folded`
//!   export. `obs::health` adds the training watchdog (NaN/Inf, loss-spike,
//!   grad-explosion and dead-spectrum checks with warn/skip/halt policies,
//!   `sct_health_*` counters, `GET /v1/health` readiness) and
//!   `rank::spectra` the per-layer spectral diagnostics behind
//!   `sct train --spectra-out` / `sct doctor` (`sct_spectral_*` gauges).
//!   Instruments serve, pool, train and rank without touching the
//!   sequential hot paths; profiling, tracing and a disarmed watchdog are
//!   one relaxed atomic load each.
//! * [`checkpoint`] — binary checkpoint format for spectral factors (shared
//!   by training sessions and serve models).
//! * [`util`] — in-tree substrates that would normally be crates (args,
//!   json, rng, bench) plus [`util::pool`], the scoped worker pool behind
//!   the parallel kernel layer: every hot matmul, the head-parallel
//!   attention kernels, the AdamW update and the per-factor QR retraction
//!   fan out through it (`--threads` / `[runtime] threads` / `SCT_THREADS`
//!   sized; fan-out threshold via `[runtime] par_threshold` /
//!   `SCT_PAR_THRESHOLD`), sharded by disjoint output rows so results are
//!   bit-identical at any thread count.

pub mod checkpoint;
pub mod coordinator;
pub mod data;
pub mod memmodel;
pub mod metrics;
pub mod obs;
pub mod rank;
pub mod runtime;
pub mod serve;
pub mod spectral;
pub mod testkit;
pub mod train;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
