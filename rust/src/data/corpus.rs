//! Synthetic instruction corpus — the Alpaca substitute.
//!
//! The paper fine-tunes SmolLM2 on Alpaca (instruction/response pairs). The
//! dataset is network-gated here, so we generate a deterministic corpus with
//! the same *shape*: templated instruction/response records over a skewed
//! (Zipf-ish) vocabulary with learnable internal structure (grammatical
//! templates, topic words that co-occur, numeric facts with consistent
//! answers). What matters for the reproduction is that the LM loss has
//! structure to learn at every model scale — the memory/throughput claims
//! never depend on data content.

use crate::util::rng::Rng;

/// Template-based instruction/response generator.
pub struct CorpusGen {
    rng: Rng,
    topics: Vec<(&'static str, Vec<&'static str>)>,
}

const VERBS: &[&str] = &["describe", "explain", "summarize", "compare", "list", "define"];
const CONNECTIVES: &[&str] =
    &["in detail", "briefly", "with examples", "for a beginner", "step by step"];

impl CorpusGen {
    pub fn new(seed: u64) -> CorpusGen {
        let topics: Vec<(&'static str, Vec<&'static str>)> = vec![
            ("matrices", vec!["rank", "factor", "column", "orthogonal", "decomposition"]),
            ("training", vec!["gradient", "optimizer", "loss", "batch", "schedule"]),
            ("memory", vec!["buffer", "cache", "footprint", "allocation", "bandwidth"]),
            ("spectra", vec!["singular", "value", "truncation", "energy", "manifold"]),
            ("models", vec!["layer", "attention", "embedding", "projection", "head"]),
        ];
        CorpusGen { rng: Rng::new(seed), topics }
    }

    fn pick<'a>(&mut self, xs: &[&'a str]) -> &'a str {
        xs[self.rng.below(xs.len())]
    }

    /// One instruction/response record.
    pub fn record(&mut self) -> String {
        let ti = self.rng.zipf(self.topics.len(), 1.3);
        let (topic, words) = (self.topics[ti].0, self.topics[ti].1.clone());
        let verb = self.pick(VERBS);
        let conn = self.pick(CONNECTIVES);
        let w1 = self.pick(&words);
        let w2 = self.pick(&words);
        // a deterministic "fact": answer depends functionally on the inputs,
        // so a model can actually reduce loss by learning the mapping.
        let a = self.rng.below(20);
        let b = self.rng.below(20);
        match self.rng.below(3) {
            0 => format!(
                "### Instruction: {verb} the {w1} of {topic} {conn}.\n### Response: the {w1} of {topic} relates to {w2}; every {w1} constrains the {w2}.\n\n"
            ),
            1 => format!(
                "### Instruction: add {a} and {b}.\n### Response: {a} plus {b} equals {}.\n\n",
                a + b
            ),
            _ => format!(
                "### Instruction: {verb} {topic} {conn}.\n### Response: {topic} uses {w1} and {w2}; the {w2} follows from the {w1}.\n\n"
            ),
        }
    }

    /// Generate text until at least `min_bytes` bytes.
    pub fn generate(&mut self, min_bytes: usize) -> String {
        let mut out = String::with_capacity(min_bytes + 256);
        while out.len() < min_bytes {
            out.push_str(&self.record());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let a = CorpusGen::new(7).generate(10_000);
        let b = CorpusGen::new(7).generate(10_000);
        assert_eq!(a, b);
        let c = CorpusGen::new(8).generate(10_000);
        assert_ne!(a, c);
    }

    #[test]
    fn has_instruction_structure() {
        let text = CorpusGen::new(1).generate(20_000);
        let n_inst = text.matches("### Instruction:").count();
        let n_resp = text.matches("### Response:").count();
        assert!(n_inst > 50);
        assert_eq!(n_inst, n_resp, "every instruction has a response");
    }

    #[test]
    fn arithmetic_facts_are_consistent() {
        // The add-a-and-b records must contain correct sums — that's the
        // learnable signal.
        let text = CorpusGen::new(2).generate(50_000);
        for line in text.lines().filter(|l| l.contains("plus")) {
            // "### Response: A plus B equals C."
            let nums: Vec<i64> = line
                .split(|c: char| !c.is_ascii_digit())
                .filter(|s| !s.is_empty())
                .map(|s| s.parse().unwrap())
                .collect();
            if nums.len() == 3 {
                assert_eq!(nums[0] + nums[1], nums[2], "bad fact: {line}");
            }
        }
    }

    #[test]
    fn vocabulary_is_skewed() {
        // Zipf topic choice: the head topic should dominate.
        let text = CorpusGen::new(3).generate(100_000);
        let counts: Vec<usize> = ["matrices", "training", "memory", "spectra", "models"]
            .iter()
            .map(|t| text.matches(t).count())
            .collect();
        let max = *counts.iter().max().unwrap();
        let min = *counts.iter().min().unwrap();
        assert!(max > 2 * min.max(1), "topic histogram should be skewed: {counts:?}");
    }
}
