//! Data substrate: tokenizer, synthetic instruction corpus (the Alpaca
//! substitute — see DESIGN.md §4), batch packing, and a threaded prefetch
//! loader with backpressure.

pub mod corpus;
pub mod dataset;
pub mod loader;
pub mod tokenizer;

pub use corpus::CorpusGen;
pub use dataset::Dataset;
pub use loader::Prefetcher;
pub use tokenizer::Tokenizer;

/// The standard tokenizer for a model vocab: byte-level when the vocab
/// covers raw bytes, otherwise BPE trained on the deterministic synthetic
/// corpus for `seed` — ONE definition of the `vocab <= 256` cutoff and the
/// 1 MiB training-text budget, shared by `sct serve` and both
/// `sct generate` backends so the selection rule cannot drift.
/// ([`build_dataset`] keeps its own caller-sized text budget: its
/// tokenizer must be trained on exactly the text it then encodes.)
pub fn tokenizer_for(vocab: usize, seed: u64) -> Tokenizer {
    if vocab <= 256 {
        Tokenizer::byte_level()
    } else {
        let text = CorpusGen::new(seed).generate(1 << 20);
        Tokenizer::train_bpe(&text, vocab)
    }
}

/// Convenience: build a tokenized dataset for a model preset.
///
/// Generates `min_bytes` of synthetic instruction text, trains a BPE
/// tokenizer to the preset's vocab (capped at what the corpus supports),
/// encodes, and wraps in a packed [`Dataset`]. Token ids are clamped into
/// the model vocab (BPE may produce fewer pieces than requested).
pub fn build_dataset(
    vocab: usize,
    batch: usize,
    seq_plus1: usize,
    min_bytes: usize,
    seed: u64,
) -> (Tokenizer, Dataset) {
    let text = CorpusGen::new(seed).generate(min_bytes);
    let tokenizer = if vocab <= 256 {
        Tokenizer::byte_level()
    } else {
        Tokenizer::train_bpe(&text, vocab)
    };
    let mut ids = tokenizer.encode(&text);
    // Clamp (paranoia: BPE ids are < vocab by construction; byte-level ids
    // can exceed a sub-256 model vocab).
    let cap = vocab as i32;
    for t in &mut ids {
        if *t >= cap {
            *t %= cap;
        }
    }
    (tokenizer, Dataset::new(ids, batch, seq_plus1, seed ^ 0x5c7))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_dataset_respects_vocab() {
        for vocab in [256usize, 512] {
            let (_tok, mut ds) = build_dataset(vocab, 4, 65, 200_000, 0);
            let b = ds.next_batch();
            assert_eq!(b.len(), 4 * 65);
            assert!(b.iter().all(|&t| (t as usize) < vocab));
        }
    }

    #[test]
    fn build_dataset_deterministic() {
        let (_, mut a) = build_dataset(512, 2, 33, 100_000, 1);
        let (_, mut b) = build_dataset(512, 2, 33, 100_000, 1);
        assert_eq!(a.next_batch(), b.next_batch());
    }
}
