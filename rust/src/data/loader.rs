//! Async prefetch loader: batch generation off the device thread.
//!
//! The PJRT client is thread-bound (see runtime::client), so the training
//! loop runs on one thread while this loader materializes upcoming batches
//! on a producer thread with a bounded channel — classic prefetch with
//! backpressure (the producer blocks when `depth` batches are waiting).
//! tokio is not vendored in this offline image; std::sync::mpsc's
//! `sync_channel` provides exactly the bounded-queue semantics needed.

use std::sync::mpsc;
use std::thread::JoinHandle;

use super::dataset::Dataset;

/// Handle to a background batch producer.
pub struct Prefetcher {
    /// `Option` so Drop can drop the receiver *before* joining the producer
    /// (a blocked `send` returns `Err` once the receiver is gone).
    rx: Option<mpsc::Receiver<Vec<i32>>>,
    handle: Option<JoinHandle<()>>,
}

impl Prefetcher {
    /// Spawn a producer yielding `chunk_k` batches per item (1 = plain
    /// batches); `depth` bounds the queue (backpressure).
    pub fn spawn(mut dataset: Dataset, chunk_k: usize, depth: usize) -> Prefetcher {
        let (tx, rx) = mpsc::sync_channel(depth);
        let handle = std::thread::Builder::new()
            .name("sct-prefetch".into())
            .spawn(move || {
                loop {
                    let item = if chunk_k <= 1 {
                        dataset.next_batch()
                    } else {
                        dataset.next_chunk(chunk_k)
                    };
                    // Receiver dropped -> training finished; exit quietly.
                    if tx.send(item).is_err() {
                        return;
                    }
                }
            })
            .expect("spawn prefetch thread");
        Prefetcher { rx: Some(rx), handle: Some(handle) }
    }

    /// Blocking fetch of the next item (producer keeps the queue warm).
    pub fn next(&self) -> Vec<i32> {
        self.rx
            .as_ref()
            .expect("prefetcher already shut down")
            .recv()
            .expect("prefetch thread died")
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        // Drop the receiver first: a producer blocked in `send` (full queue)
        // gets an Err immediately and exits, so the join cannot hang.
        drop(self.rx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dataset(seed: u64) -> Dataset {
        Dataset::new((0..5000).collect(), 2, 10, seed)
    }

    #[test]
    fn prefetch_matches_inline_iteration() {
        let pf = Prefetcher::spawn(dataset(0), 1, 4);
        let mut inline = dataset(0);
        for _ in 0..20 {
            assert_eq!(pf.next(), inline.next_batch());
        }
    }

    #[test]
    fn prefetch_chunks() {
        let pf = Prefetcher::spawn(dataset(1), 3, 2);
        let mut inline = dataset(1);
        for _ in 0..5 {
            assert_eq!(pf.next(), inline.next_chunk(3));
        }
    }

    #[test]
    fn drop_terminates_producer() {
        let pf = Prefetcher::spawn(dataset(2), 1, 2);
        let _ = pf.next();
        drop(pf); // must not hang
    }

    #[test]
    fn drop_under_load_joins_blocked_producer() {
        // Regression for the dummy-channel Drop hack: with a full queue the
        // producer sits blocked in `send`; dropping the Prefetcher must wake
        // it (receiver gone => send errors) and join, never deadlock. Repeat
        // to catch both block-in-send and between-sends timings.
        for i in 0..20u64 {
            let pf = Prefetcher::spawn(dataset(i), 1, 1);
            // Give the producer time to fill the queue and block in send.
            if i % 2 == 0 {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            drop(pf);
        }
    }

    #[test]
    fn bounded_queue_applies_backpressure() {
        // With depth 2 and no consumption, the producer fills the queue and
        // blocks rather than buffering unboundedly. We can't observe the
        // block directly, but after a grace period only depth+1 items can
        // have been produced; consuming them all still works.
        let pf = Prefetcher::spawn(dataset(3), 1, 2);
        std::thread::sleep(std::time::Duration::from_millis(50));
        for _ in 0..10 {
            assert_eq!(pf.next().len(), 20);
        }
    }
}
