//! Tokenizers: byte-level fallback and a trainable mini-BPE.
//!
//! The paper fine-tunes on Alpaca with the SmolLM2 tokenizer — both gated
//! here (no network), so the data substrate provides its own: a BPE trained
//! on the synthetic corpus, with byte-level as the degenerate case. The
//! training loop only cares that token ids are < vocab and round-trip.

use std::collections::HashMap;

/// A trained BPE vocabulary (byte-level base, learned merges).
#[derive(Debug, Clone)]
pub struct Tokenizer {
    /// merge ranks: (left, right) -> merged token id, in training order.
    merges: Vec<((u32, u32), u32)>,
    merge_lookup: HashMap<(u32, u32), u32>,
    /// token id -> byte string (for decoding).
    pieces: Vec<Vec<u8>>,
    pub vocab_size: usize,
}

impl Tokenizer {
    /// Byte-level tokenizer: ids 0..255 are raw bytes, no merges.
    pub fn byte_level() -> Tokenizer {
        Tokenizer {
            merges: Vec::new(),
            merge_lookup: HashMap::new(),
            pieces: (0..=255u16).map(|b| vec![b as u8]).collect(),
            vocab_size: 256,
        }
    }

    /// Train BPE on `text` until `vocab_size` tokens (>= 256) exist.
    ///
    /// Classic algorithm: repeatedly merge the most frequent adjacent pair.
    /// Counts are recomputed per merge over the working sequence — O(merges
    /// * corpus), fine for the corpus sizes the drivers use (<= a few MB).
    pub fn train_bpe(text: &str, vocab_size: usize) -> Tokenizer {
        assert!(vocab_size >= 256, "vocab must cover raw bytes");
        let mut tok = Tokenizer::byte_level();
        tok.vocab_size = vocab_size;
        let mut seq: Vec<u32> = text.bytes().map(|b| b as u32).collect();

        while tok.pieces.len() < vocab_size {
            // count adjacent pairs
            let mut counts: HashMap<(u32, u32), usize> = HashMap::new();
            for w in seq.windows(2) {
                *counts.entry((w[0], w[1])).or_default() += 1;
            }
            // deterministic argmax: count desc, then pair asc
            let Some((&pair, &cnt)) = counts
                .iter()
                .max_by(|(p1, c1), (p2, c2)| c1.cmp(c2).then(p2.cmp(p1)))
            else {
                break;
            };
            if cnt < 2 {
                break; // nothing worth merging
            }
            let new_id = tok.pieces.len() as u32;
            let mut piece = tok.pieces[pair.0 as usize].clone();
            piece.extend_from_slice(&tok.pieces[pair.1 as usize]);
            tok.pieces.push(piece);
            tok.merges.push((pair, new_id));
            tok.merge_lookup.insert(pair, new_id);
            // apply the merge to the working sequence
            seq = apply_merge(&seq, pair, new_id);
        }
        tok.vocab_size = tok.pieces.len().max(vocab_size.min(tok.pieces.len()));
        tok.vocab_size = tok.pieces.len();
        tok
    }

    /// Encode text to token ids (applies merges in training order).
    pub fn encode(&self, text: &str) -> Vec<i32> {
        let mut seq: Vec<u32> = text.bytes().map(|b| b as u32).collect();
        // Apply merges in rank order (training order = priority order).
        for &(pair, id) in &self.merges {
            if seq.len() < 2 {
                break;
            }
            seq = apply_merge(&seq, pair, id);
        }
        seq.into_iter().map(|t| t as i32).collect()
    }

    /// Decode token ids back to text (lossy only on invalid UTF-8 joins).
    pub fn decode(&self, ids: &[i32]) -> String {
        let mut bytes = Vec::new();
        for &id in ids {
            if let Some(p) = self.pieces.get(id as usize) {
                bytes.extend_from_slice(p);
            }
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }

    pub fn piece(&self, id: u32) -> Option<&[u8]> {
        self.pieces.get(id as usize).map(|v| v.as_slice())
    }
}

fn apply_merge(seq: &[u32], pair: (u32, u32), id: u32) -> Vec<u32> {
    let mut out = Vec::with_capacity(seq.len());
    let mut i = 0;
    while i < seq.len() {
        if i + 1 < seq.len() && seq[i] == pair.0 && seq[i + 1] == pair.1 {
            out.push(id);
            i += 2;
        } else {
            out.push(seq[i]);
            i += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_level_roundtrip() {
        let t = Tokenizer::byte_level();
        let s = "hello, Stiefel manifold! éü";
        assert_eq!(t.decode(&t.encode(s)), s);
        assert!(t.encode(s).iter().all(|&id| id < 256));
    }

    #[test]
    fn bpe_roundtrip_and_compresses() {
        let corpus = "the quick brown fox jumps over the lazy dog. ".repeat(50);
        let t = Tokenizer::train_bpe(&corpus, 300);
        let ids = t.encode(&corpus);
        assert_eq!(t.decode(&ids), corpus, "lossless round-trip");
        let byte_len = corpus.len();
        assert!(
            ids.len() < byte_len / 2,
            "BPE should compress repetitive text: {} vs {byte_len}",
            ids.len()
        );
        assert!(ids.iter().all(|&id| (id as usize) < t.vocab_size));
    }

    #[test]
    fn bpe_is_deterministic() {
        let corpus = "abcabcabc abcabc xyz xyz".repeat(20);
        let a = Tokenizer::train_bpe(&corpus, 280);
        let b = Tokenizer::train_bpe(&corpus, 280);
        assert_eq!(a.encode(&corpus), b.encode(&corpus));
    }

    #[test]
    fn bpe_handles_unseen_text() {
        let t = Tokenizer::train_bpe(&"hello world ".repeat(30), 280);
        let unseen = "completely different zebra text 123";
        assert_eq!(t.decode(&t.encode(unseen)), unseen);
    }

    #[test]
    fn merge_application() {
        let seq = vec![1, 2, 1, 2, 3];
        assert_eq!(apply_merge(&seq, (1, 2), 9), vec![9, 9, 3]);
        // overlapping pairs are left-greedy
        let seq = vec![1, 1, 1];
        assert_eq!(apply_merge(&seq, (1, 1), 9), vec![9, 1]);
    }

    #[test]
    fn training_stops_when_no_repeats() {
        // All-unique text: no pair occurs twice; vocab stays at 256.
        let t = Tokenizer::train_bpe("abcdefghijklmnop", 512);
        assert_eq!(t.vocab_size, 256);
    }
}
