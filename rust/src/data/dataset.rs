//! Token stream -> packed training batches.
//!
//! Packing follows the standard causal-LM recipe: the token stream is cut
//! into contiguous windows of `seq_len + 1` (inputs + shifted targets share
//! one tensor; the graph slices internally), batch `b` such windows, shuffle
//! window order per epoch with a seeded RNG.

use crate::util::rng::Rng;

/// An epoch-shuffled, packed batch iterator over a token stream.
pub struct Dataset {
    tokens: Vec<i32>,
    pub batch: usize,
    pub seq_plus1: usize,
    order: Vec<usize>,
    cursor: usize,
    rng: Rng,
    pub epoch: u64,
}

impl Dataset {
    /// `seq_plus1` = seq_len + 1 (the wire shape of the tokens tensor).
    pub fn new(tokens: Vec<i32>, batch: usize, seq_plus1: usize, seed: u64) -> Dataset {
        assert!(
            tokens.len() >= batch * seq_plus1,
            "corpus too small: {} tokens for batch {batch} x {seq_plus1}",
            tokens.len()
        );
        let n_windows = tokens.len() / seq_plus1;
        let mut ds = Dataset {
            tokens,
            batch,
            seq_plus1,
            order: (0..n_windows).collect(),
            cursor: 0,
            rng: Rng::new(seed),
            epoch: 0,
        };
        ds.rng.shuffle(&mut ds.order);
        ds
    }

    pub fn n_windows(&self) -> usize {
        self.order.len()
    }

    pub fn batches_per_epoch(&self) -> usize {
        self.n_windows() / self.batch
    }

    /// Next batch as a flat (batch * seq_plus1) i32 buffer (row-major).
    /// Reshuffles and bumps `epoch` at epoch end.
    pub fn next_batch(&mut self) -> Vec<i32> {
        if self.cursor + self.batch > self.order.len() {
            self.rng.shuffle(&mut self.order);
            self.cursor = 0;
            self.epoch += 1;
        }
        let mut out = Vec::with_capacity(self.batch * self.seq_plus1);
        for i in 0..self.batch {
            let w = self.order[self.cursor + i];
            let start = w * self.seq_plus1;
            out.extend_from_slice(&self.tokens[start..start + self.seq_plus1]);
        }
        self.cursor += self.batch;
        out
    }

    /// Next K batches concatenated — the train_chunk wire format
    /// (k, batch, seq+1) row-major.
    pub fn next_chunk(&mut self, k: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(k * self.batch * self.seq_plus1);
        for _ in 0..k {
            out.extend(self.next_batch());
        }
        out
    }

    /// A fixed held-out batch (deterministic, last windows — never yielded
    /// by `next_batch` when the window count isn't a multiple of batch;
    /// used for eval loss).
    pub fn eval_batch(&self) -> Vec<i32> {
        let mut out = Vec::with_capacity(self.batch * self.seq_plus1);
        for i in 0..self.batch {
            let w = (self.n_windows() - 1 - i) % self.n_windows();
            let start = w * self.seq_plus1;
            out.extend_from_slice(&self.tokens[start..start + self.seq_plus1]);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(n: usize) -> Vec<i32> {
        (0..n as i32).collect()
    }

    #[test]
    fn batch_shape_and_alignment() {
        let mut ds = Dataset::new(toy(1000), 4, 9, 0);
        let b = ds.next_batch();
        assert_eq!(b.len(), 36);
        // every row must be a contiguous window aligned to seq_plus1
        for r in 0..4 {
            let row = &b[r * 9..(r + 1) * 9];
            assert_eq!(row[0] % 9, 0, "window must start at a multiple of 9");
            for (i, w) in row.windows(2).enumerate() {
                assert_eq!(w[1], w[0] + 1, "row {r} pos {i} not contiguous");
            }
        }
    }

    #[test]
    fn no_token_loss_within_epoch() {
        // Over one epoch every window index is yielded exactly once.
        let mut ds = Dataset::new(toy(20 * 5), 2, 5, 1);
        let per_epoch = ds.batches_per_epoch();
        let mut starts = Vec::new();
        for _ in 0..per_epoch {
            let b = ds.next_batch();
            starts.push(b[0] / 5);
            starts.push(b[5] / 5);
        }
        starts.sort_unstable();
        starts.dedup();
        assert_eq!(starts.len(), per_epoch * 2, "duplicate windows within an epoch");
    }

    #[test]
    fn epochs_reshuffle() {
        let mut ds = Dataset::new(toy(40 * 7), 2, 7, 2);
        let e0: Vec<i32> = (0..ds.batches_per_epoch()).flat_map(|_| ds.next_batch()).collect();
        assert_eq!(ds.epoch, 0);
        let e1: Vec<i32> = (0..ds.batches_per_epoch()).flat_map(|_| ds.next_batch()).collect();
        assert_eq!(ds.epoch, 1);
        assert_ne!(e0, e1, "epoch order should differ");
        // but the multiset of tokens is identical
        let (mut a, mut b) = (e0.clone(), e1.clone());
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
    }

    #[test]
    fn deterministic_for_seed() {
        let mut a = Dataset::new(toy(500), 2, 10, 3);
        let mut b = Dataset::new(toy(500), 2, 10, 3);
        for _ in 0..10 {
            assert_eq!(a.next_batch(), b.next_batch());
        }
    }

    #[test]
    fn chunk_is_k_batches() {
        let mut a = Dataset::new(toy(2000), 2, 10, 4);
        let mut b = Dataset::new(toy(2000), 2, 10, 4);
        let chunk = a.next_chunk(3);
        let loose: Vec<i32> = (0..3).flat_map(|_| b.next_batch()).collect();
        assert_eq!(chunk, loose);
    }

    #[test]
    #[should_panic(expected = "corpus too small")]
    fn rejects_tiny_corpus() {
        Dataset::new(toy(10), 4, 9, 0);
    }
}
