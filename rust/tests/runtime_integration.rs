//! Integration tests: rust loads the AOT artifacts and drives real training
//! steps through PJRT. Requires `make artifacts` (skips cleanly otherwise).
//!
//! This is the end-to-end proof of the three-layer contract: Pallas/JAX
//! lowered the training step once at build time; everything below here is
//! rust + compiled HLO.

// PJRT execution only exists behind the `pjrt` feature.
#![cfg(feature = "pjrt")]

use sct::runtime::{Manifest, Session};

fn artifacts_root() -> Option<std::path::PathBuf> {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    root.join("manifest.json").exists().then_some(root)
}

fn tiny_session() -> Option<Session> {
    let root = artifacts_root()?;
    let m = Manifest::load(&root).ok()?;
    if !m.presets.contains_key("tiny_r8") {
        return None;
    }
    Some(Session::open(&root, "tiny_r8").expect("open session"))
}

/// Deterministic token batch that is learnable (fixed repeating pattern).
fn batch(seed: i32, n: usize, vocab: usize) -> Vec<i32> {
    (0..n).map(|i| ((i as i64 * 31 + seed as i64 * 7) % vocab as i64) as i32).collect()
}

#[test]
fn init_then_train_loss_decreases() {
    let Some(mut s) = tiny_session() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    s.init(0).unwrap();
    let spec = s.preset.tokens_spec().unwrap().clone();
    let toks = batch(1, spec.elements(), s.preset.model.vocab);

    let first = s.train_step(&toks, 1e-3, 5e-3).unwrap();
    let mut last = first;
    for _ in 0..9 {
        last = s.train_step(&toks, 1e-3, 5e-3).unwrap();
    }
    assert!(first.is_finite() && last.is_finite());
    // Same batch 10x: the model must overfit toward it.
    assert!(
        last < first - 0.05,
        "loss should decrease on a repeated batch: first={first} last={last}"
    );
    assert_eq!(s.steps_done, 10);
}

#[test]
fn orthonormality_maintained_through_training() {
    let Some(mut s) = tiny_session() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    s.init(3).unwrap();
    let err0 = s.ortho_check().unwrap();
    assert!(err0 < 2e-6, "ortho error at init: {err0}");
    let spec = s.preset.tokens_spec().unwrap().clone();
    for i in 0..5 {
        let toks = batch(i, spec.elements(), s.preset.model.vocab);
        s.train_step(&toks, 1e-3, 5e-3).unwrap();
    }
    // Paper Table 2: ortho error < 2e-6 after full step incl. retraction.
    let err = s.ortho_check().unwrap();
    assert!(err < 2e-6, "ortho error after training: {err}");
}

#[test]
fn train_chunk_matches_loop_of_steps() {
    let Some(root) = artifacts_root() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut a = Session::open(&root, "tiny_r8").unwrap();
    let mut b = Session::open(&root, "tiny_r8").unwrap();
    a.init(7).unwrap();
    b.init(7).unwrap();

    let k = a.chunk_len().expect("train_chunk exported");
    let spec = a.preset.tokens_spec().unwrap().clone();
    let per = spec.elements();
    let mut all = Vec::new();
    for i in 0..k {
        all.extend(batch(i as i32, per, a.preset.model.vocab));
    }

    // a: one fused chunk; b: k individual steps on the same batches.
    let losses_a = a.train_chunk(&all, 1e-3, 5e-3).unwrap();
    let mut losses_b = Vec::new();
    for i in 0..k {
        let toks = &all[i * per..(i + 1) * per];
        losses_b.push(b.train_step(toks, 1e-3, 5e-3).unwrap());
    }
    assert_eq!(losses_a.len(), k);
    for (i, (la, lb)) in losses_a.iter().zip(&losses_b).enumerate() {
        assert!(
            (la - lb).abs() < 1e-4 * lb.abs().max(1.0),
            "chunk step {i}: fused={la} loop={lb}"
        );
    }
}

#[test]
fn eval_and_forward_are_consistent() {
    let Some(mut s) = tiny_session() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    s.init(5).unwrap();
    let spec = s.preset.tokens_spec().unwrap().clone();
    let toks = batch(2, spec.elements(), s.preset.model.vocab);
    let eval = s.eval_step(&toks).unwrap();
    assert!(eval.is_finite() && eval > 0.0);
    // Forward on the input slice (B, T) — manifest records (B, T) for the
    // forward artifact; build its tokens from the same batch.
    let fwd_spec = s.preset.artifact("forward").unwrap();
    let ti = fwd_spec.input_index("tokens").unwrap();
    let fwd_elems = fwd_spec.inputs[ti].elements();
    let (b_, t1) = (spec.shape[0], spec.shape[1]);
    let t = t1 - 1;
    let mut fwd_toks = Vec::with_capacity(fwd_elems);
    for r in 0..b_ {
        fwd_toks.extend_from_slice(&toks[r * t1..r * t1 + t]);
    }
    let (shape, logits) = s.forward(&fwd_toks).unwrap();
    assert_eq!(shape, vec![b_, t, s.preset.model.vocab]);
    assert!(logits.iter().all(|x| x.is_finite()));
    // Cross-check: eval loss == mean NLL computed from forward logits.
    let v = s.preset.model.vocab;
    let mut nll = 0.0f64;
    for r in 0..b_ {
        for pos in 0..t {
            let row = &logits[(r * t + pos) * v..(r * t + pos + 1) * v];
            let target = toks[r * t1 + pos + 1] as usize;
            let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let lse = mx + row.iter().map(|x| (x - mx).exp()).sum::<f32>().ln();
            nll += (lse - row[target]) as f64;
        }
    }
    let nll = (nll / (b_ * t) as f64) as f32;
    assert!(
        (nll - eval).abs() < 1e-3 * eval.max(1.0),
        "manual NLL {nll} vs eval {eval}"
    );
}

#[test]
fn deterministic_from_seed() {
    let Some(root) = artifacts_root() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut a = Session::open(&root, "tiny_r8").unwrap();
    let mut b = Session::open(&root, "tiny_r8").unwrap();
    a.init(42).unwrap();
    b.init(42).unwrap();
    let spec = a.preset.tokens_spec().unwrap().clone();
    let toks = batch(9, spec.elements(), a.preset.model.vocab);
    let la = a.train_step(&toks, 1e-3, 5e-3).unwrap();
    let lb = b.train_step(&toks, 1e-3, 5e-3).unwrap();
    assert_eq!(la, lb, "same seed + same batch must be bit-identical");
}

#[test]
fn retract_is_idempotent_on_fresh_state() {
    let Some(mut s) = tiny_session() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    s.init(1).unwrap();
    let (shape, before) = s.tensor_f32("params/layers/0/mlp/gate/u").unwrap();
    s.retract().unwrap();
    let (_, after) = s.tensor_f32("params/layers/0/mlp/gate/u").unwrap();
    assert_eq!(shape.len(), 2);
    let max_diff = before
        .iter()
        .zip(&after)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    // Already orthonormal -> QR retraction is (numerically) the identity.
    assert!(max_diff < 1e-5, "retract changed an orthonormal factor by {max_diff}");
}

#[test]
fn set_tensor_roundtrip_and_validation() {
    let Some(mut s) = tiny_session() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    s.init(0).unwrap();
    let (shape, mut data) = s.tensor_f32("params/embed").unwrap();
    data[0] = 123.5;
    s.set_tensor("params/embed", &shape, &data).unwrap();
    let (_, back) = s.tensor_f32("params/embed").unwrap();
    assert_eq!(back[0], 123.5);
    // Wrong shape must be rejected.
    assert!(s.set_tensor("params/embed", &[1, 2], &[0.0, 0.0]).is_err());
    // Unknown names must be rejected.
    assert!(s.set_tensor("params/nope", &shape, &data).is_err());
}
