//! Determinism contract of the parallel kernel layer (`util::pool`):
//! every parallel path — the matmuls, head-parallel attention, the AdamW
//! update, the retraction fan-out, fused prefill — must be **bit-identical**
//! at any thread count, because work is sharded by disjoint output rows /
//! stripes with the serial kernel's accumulation order preserved.
//!
//! The canonical serial kernel these tests pin is `spectral::microkernel`'s
//! cache-blocked GEBP / fused-dot layer: the invariant is "bit-identical at
//! any thread count against the blocked accumulation order", NOT
//! "bit-identical to the old scalar loops" (this file was re-pinned when
//! the blocked kernels replaced them). The blocked order is fixed by the
//! shared-dimension length alone, so shard boundaries, MR×NR tile
//! remainders, the packed-vs-stream path split and the AVX2-vs-scalar
//! dispatch all reproduce the same bits — the shape sweep below includes
//! tile-remainder edges (m % 8 ≠ 0, n % 8 ≠ 0, k ragged) to prove it.
//!
//! `pool::set_force_parallel(true)` bypasses the work thresholds so the
//! parallel code paths run even at test-sized shapes. The pool size is a
//! process-global, so every test in this file serializes on [`lock`]: a
//! concurrent test changing the thread count mid-reference would not change
//! any *result* (that IS the invariant), but it could silently compute the
//! "1-thread" reference at 4 threads — and a comparison of 4-thread against
//! 4-thread output would no longer detect a divergence regression.

use std::sync::{Mutex, MutexGuard, OnceLock};

use sct::serve::engine::{Engine, EngineConfig, SampleOpts, SpectralModel};
use sct::spectral::{AdamW, Matrix};
use sct::train::blocks::Rope;
use sct::train::decoder::{decoder_bwd, decoder_fwd};
use sct::train::{NativeTrainConfig, NativeTrainer};
use sct::util::pool;
use sct::util::rng::Rng;

const THREAD_COUNTS: [usize; 3] = [1, 2, 4];

/// Serializes the tests in this binary (they all mutate the global pool
/// size). Poison from an earlier panicking test is irrelevant — take the
/// guard either way.
fn lock() -> MutexGuard<'static, ()> {
    static GATE: OnceLock<Mutex<()>> = OnceLock::new();
    match GATE.get_or_init(|| Mutex::new(())).lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

fn tiny_model_cfg() -> EngineConfig {
    EngineConfig {
        vocab: 32,
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ffn: 24,
        rank: 3,
        max_seq: 32,
        tied: true,
    }
}

#[test]
fn matmul_kernels_bit_identical_across_thread_counts() {
    let _gate = lock();
    pool::set_force_parallel(true);
    let mut rng = Rng::new(1);
    // (m, k, n) sweep hitting the blocked kernel's edges: ragged k, both
    // tile remainders (m % 8, n % 8), exact-tile shapes, fewer rows than
    // the MIN_PACK_ROWS stream/pack split, single-row, and n < NR so
    // matmul_t's dot8 column tiling never engages.
    for &(m, k, n) in &[
        (37usize, 19usize, 23usize),
        (8, 8, 8),
        (64, 33, 32),
        (9, 17, 5),
        (5, 1, 9),
        (3, 7, 16),
        (1, 7, 3),
    ] {
        let a = Matrix::randn(&mut rng, m, k, 1.0);
        let b = Matrix::randn(&mut rng, k, n, 1.0);
        let c = Matrix::randn(&mut rng, m, n, 1.0); // t_matmul: shared dim m
        let d = Matrix::randn(&mut rng, n, k, 1.0); // matmul_t: n output cols
        let k_eff = k.div_ceil(2);

        pool::set_threads(1);
        let mm = a.matmul(&b);
        let tm = a.t_matmul(&c);
        let mt = a.matmul_t(&d);
        let mtp = a.matmul_t_prefix(&d, k_eff);
        for &t in &THREAD_COUNTS[1..] {
            pool::set_threads(t);
            assert_eq!(a.matmul(&b).data, mm.data, "matmul {m}x{k}x{n} diverged at {t} threads");
            assert_eq!(
                a.t_matmul(&c).data,
                tm.data,
                "t_matmul {m}x{k}x{n} diverged at {t} threads"
            );
            assert_eq!(
                a.matmul_t(&d).data,
                mt.data,
                "matmul_t {m}x{k}x{n} diverged at {t} threads"
            );
            assert_eq!(
                a.matmul_t_prefix(&d, k_eff).data,
                mtp.data,
                "matmul_t_prefix {m}x{k}x{n} (k_eff {k_eff}) diverged at {t} threads"
            );
        }
    }
}

#[test]
fn adamw_update_bit_identical_across_thread_counts() {
    let _gate = lock();
    pool::set_force_parallel(true);
    let n = 10_007; // odd length: uneven worker chunks
    let grads: Vec<f32> = (0..n).map(|i| ((i * 37) as f32 * 0.01).sin()).collect();
    let mut reference = None;
    for &t in &THREAD_COUNTS {
        pool::set_threads(t);
        let mut opt = AdamW::new(n, 0.01);
        opt.weight_decay = 0.1;
        let mut p: Vec<f32> = (0..n).map(|i| ((i * 13) as f32 * 0.02).cos()).collect();
        for _ in 0..3 {
            opt.step(&mut p, &grads);
        }
        match &reference {
            None => reference = Some(p),
            Some(r) => assert_eq!(&p, r, "AdamW diverged at {t} threads"),
        }
    }
}

#[test]
fn decoder_forward_and_backward_bit_identical_across_thread_counts() {
    let _gate = lock();
    pool::set_force_parallel(true);
    let model = SpectralModel::init(tiny_model_cfg(), 5);
    let rope = Rope::new(model.cfg.max_seq, model.cfg.head_dim());
    let (b, t_len) = (2usize, 8usize);
    let mut rng = Rng::new(6);
    let tokens: Vec<i32> =
        (0..b * t_len).map(|_| (rng.next_u64() % model.cfg.vocab as u64) as i32).collect();
    let dlogits = Matrix::randn(&mut rng, b * t_len, model.cfg.vocab, 1.0);

    pool::set_threads(1);
    let (logits_ref, cache) = decoder_fwd(&model, &rope, &tokens, b, t_len);
    let grads_ref = decoder_bwd(&model, &rope, &tokens, b, t_len, &cache, &dlogits);

    for &t in &THREAD_COUNTS[1..] {
        pool::set_threads(t);
        let (logits, cache) = decoder_fwd(&model, &rope, &tokens, b, t_len);
        assert_eq!(logits.data, logits_ref.data, "forward logits diverged at {t} threads");
        let grads = decoder_bwd(&model, &rope, &tokens, b, t_len, &cache, &dlogits);
        assert_eq!(grads.embed.data, grads_ref.embed.data, "embed grad at {t} threads");
        assert_eq!(grads.ln_f, grads_ref.ln_f, "ln_f grad at {t} threads");
        for (l, (g, gr)) in grads.layers.iter().zip(&grads_ref.layers).enumerate() {
            assert_eq!(g.wq.data, gr.wq.data, "layer {l} wq grad at {t} threads");
            assert_eq!(g.wo.data, gr.wo.data, "layer {l} wo grad at {t} threads");
            assert_eq!(g.ln1, gr.ln1, "layer {l} ln1 grad at {t} threads");
            assert_eq!(g.gate.du.data, gr.gate.du.data, "layer {l} gate.du at {t} threads");
            assert_eq!(g.gate.ds, gr.gate.ds, "layer {l} gate.ds at {t} threads");
            assert_eq!(g.down.dv.data, gr.down.dv.data, "layer {l} down.dv at {t} threads");
        }
    }
}

#[test]
fn native_training_run_bit_identical_across_thread_counts() {
    let _gate = lock();
    pool::set_force_parallel(true);
    let cfg = NativeTrainConfig {
        model: tiny_model_cfg(),
        batch: 2,
        seq_len: 8,
        grad_clip: 1.0,
        retract_every: 1,
        weight_decay: 0.01,
    };
    let window = cfg.batch * (cfg.seq_len + 1);
    let batch_at = |step: usize| -> Vec<i32> {
        (0..window).map(|i| ((step * 5 + i * 3) % 8) as i32).collect()
    };

    let run = |threads: usize| -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        pool::set_threads(threads);
        let mut trainer = NativeTrainer::new(cfg, 9);
        let mut losses = Vec::new();
        for step in 0..20 {
            let (l, _) = trainer.train_step(&batch_at(step), 3e-3, 3e-3);
            losses.push(l);
        }
        (
            losses,
            trainer.model.embed.data.clone(),
            trainer.model.layers[0].gate.u.data.clone(),
        )
    };

    let (losses_ref, embed_ref, u_ref) = run(1);
    assert!(losses_ref.iter().all(|l| l.is_finite()));
    for &t in &THREAD_COUNTS[1..] {
        let (losses, embed, u) = run(t);
        assert_eq!(losses, losses_ref, "20-step loss trajectory diverged at {t} threads");
        assert_eq!(embed, embed_ref, "embeddings diverged at {t} threads");
        assert_eq!(u, u_ref, "retracted factor diverged at {t} threads");
    }
}

#[test]
fn serve_decode_token_identical_across_threads_and_prefill_modes() {
    let _gate = lock();
    pool::set_force_parallel(true);
    let cfg = EngineConfig {
        vocab: 50,
        d_model: 32,
        n_layers: 2,
        n_heads: 4,
        d_ffn: 48,
        rank: 4,
        max_seq: 64,
        tied: true,
    };
    let e = Engine::new(SpectralModel::init(cfg, 3));
    let opts = SampleOpts { temperature: 0.0, top_k: 0, seed: 0 };
    let prompt: Vec<i32> = (0..20).map(|i| (i * 7 + 1) % 50).collect();

    // greedy decode across thread counts (fused prefill inside generate_kv)
    let mut outs: Vec<Vec<i32>> = Vec::new();
    for &t in &THREAD_COUNTS {
        pool::set_threads(t);
        let mut kv = e.new_kv(1);
        let slot = kv.alloc().unwrap();
        outs.push(e.generate_kv(&prompt, 10, &opts, &mut kv, slot));
    }
    assert_eq!(outs[0].len(), 10);
    assert_eq!(outs[0], outs[1], "decode diverged between 1 and 2 threads");
    assert_eq!(outs[0], outs[2], "decode diverged between 1 and 4 threads");

    // fused whole-prompt prefill vs per-position prefill: logits bit-equal
    pool::set_threads(4);
    let mut kv = e.new_kv(2);
    let fused = kv.alloc().unwrap();
    e.prefill(&prompt[..19], fused, &mut kv);
    let l_fused = e.step_batch(&[prompt[19]], &[fused], &mut kv);
    let per_pos = kv.alloc().unwrap();
    for &t in &prompt[..19] {
        e.prefill_batch(&[t], &[per_pos], &mut kv);
    }
    let l_per_pos = e.step_batch(&[prompt[19]], &[per_pos], &mut kv);
    assert_eq!(
        l_fused.data, l_per_pos.data,
        "fused prefill must be bit-identical to per-position prefill"
    );
}
