//! Property-based tests over the rust substrates (in-tree testkit — the
//! offline image has no proptest crate). Each property runs dozens to
//! hundreds of seeded cases; failures print the seed + generation log.

use sct::checkpoint::{read_checkpoint, write_checkpoint, NamedTensor};
use sct::coordinator::config::{parse_toml, TomlValue};
use sct::coordinator::schedule::Schedule;
use sct::data::{Dataset, Tokenizer};
use sct::memmodel::layer::{LayerMemory, TrainRegime};
use sct::spectral::{qr_householder, qr_retract, svd, Matrix, SpectralLinear};
use sct::testkit::{Gen, Prop};
use sct::util::json::Json;

// ---------------------------------------------------------------------------
// spectral math
// ---------------------------------------------------------------------------

/// Per-element check of a blocked-kernel product against an exact f64
/// triple-loop reference, under a k-scaled ulp bound: a fused f32 fold of
/// length `kdim` carries at most ~`kdim` roundings of the running sum (plus
/// the 8-lane reduction tree), each bounded by eps times the partial-sum
/// magnitude, so `|got - exact| <= (kdim + 8) * eps * Σ_k |a_ik * b_kj|`
/// (plus a denormal floor). `a_at(i, k)` / `b_at(k, j)` index the logical
/// operands of `got[i][j] = Σ_k a_at(i,k) * b_at(k,j)`.
fn check_against_naive(
    g: &mut Gen,
    label: &str,
    got: &Matrix,
    kdim: usize,
    a_at: &dyn Fn(usize, usize) -> f32,
    b_at: &dyn Fn(usize, usize) -> f32,
) {
    for i in 0..got.rows {
        for j in 0..got.cols {
            let mut exact = 0.0f64;
            let mut abs = 0.0f64;
            for k in 0..kdim {
                let p = a_at(i, k) as f64 * b_at(k, j) as f64;
                exact += p;
                abs += p.abs();
            }
            let tol = (kdim as f64 + 8.0) * f32::EPSILON as f64 * abs + 1e-30;
            let err = (got[(i, j)] as f64 - exact).abs();
            g.check(err <= tol, &format!("{label} ({i},{j}): err {err} > tol {tol}"));
        }
    }
}

#[test]
fn prop_blocked_matmuls_match_naive_reference() {
    Prop::new("blocked kernels == naive triple loop").cases(150).run(|g| {
        // Inclusive ranges from 0 hit the degenerates (0×n, 1×1, k=1) and
        // every tile-remainder class (m%8 ≠ 0, n%8 ≠ 0, ragged k) over the
        // run, on both sides of the pack/stream and dot8/remainder splits.
        let m = g.usize(0, 21);
        let kdim = g.usize(0, 35);
        let n = g.usize(0, 19);
        let a = g.matrix(m, kdim, 1.0);
        let b = g.matrix(kdim, n, 1.0);
        check_against_naive(g, "matmul", &a.matmul(&b), kdim, &|i, k| a[(i, k)], &|k, j| {
            b[(k, j)]
        });

        let at = g.matrix(kdim, m, 1.0); // t_matmul: shared dim = rows
        check_against_naive(g, "t_matmul", &at.t_matmul(&b), kdim, &|i, k| at[(k, i)], &|k, j| {
            b[(k, j)]
        });

        let bt = g.matrix(n, kdim, 1.0);
        check_against_naive(g, "matmul_t", &a.matmul_t(&bt), kdim, &|i, k| a[(i, k)], &|k, j| {
            bt[(j, k)]
        });
    });
}

#[test]
fn prop_matmul_t_prefix_bitwise_equals_truncated() {
    Prop::new("prefix == truncated matmul_t (bitwise)").cases(120).run(|g| {
        let m = g.usize(0, 16);
        let kdim = g.usize(0, 24);
        let n = g.usize(0, 14);
        let k_eff = g.usize(0, kdim);
        let a = g.matrix(m, kdim, 1.0);
        let b = g.matrix(n, kdim, 1.0);
        // The canonical dot's structure depends only on the dotted length,
        // so the prefix product must be bit-identical to physically
        // truncating both operands to k_eff columns first.
        let truncate = |src: &Matrix| {
            let mut t = Matrix::zeros(src.rows, k_eff);
            for r in 0..src.rows {
                t.row_mut(r).copy_from_slice(&src.row(r)[..k_eff]);
            }
            t
        };
        let pref = a.matmul_t_prefix(&b, k_eff);
        let trunc = truncate(&a).matmul_t(&truncate(&b));
        g.check(pref.data == trunc.data, "prefix product != truncated product (bitwise)");
        check_against_naive(g, "matmul_t_prefix", &pref, k_eff, &|i, k| a[(i, k)], &|k, j| {
            b[(j, k)]
        });
    });
}

#[test]
fn prop_blocked_transpose_exact() {
    Prop::new("blocked transpose exact + involutive").cases(80).run(|g| {
        // up to 70: straddles the 32-wide tile boundary in both dimensions
        let m = g.usize(0, 70);
        let n = g.usize(0, 70);
        let a = g.matrix(m, n, 1.0);
        let t = a.transpose();
        g.check(t.rows == n && t.cols == m, "transpose shape wrong");
        let mut exact = true;
        for r in 0..m {
            for c in 0..n {
                exact &= t[(c, r)].to_bits() == a[(r, c)].to_bits();
            }
        }
        g.check(exact, "transpose moved bits");
        g.check(t.transpose() == a, "transpose not involutive");
    });
}

#[test]
fn prop_qr_retract_orthonormal_and_span() {
    Prop::new("qr orthonormal+span").cases(120).run(|g| {
        let m = g.usize(2, 96);
        let k = g.usize(1, m.min(24));
        let scale = g.f32(0.1, 10.0);
        let a = g.matrix(m, k, scale);
        let q = qr_retract(&a);
        g.check(q.ortho_error() < 2e-6, "ortho error >= 2e-6");
        let recon = q.matmul(&q.t_matmul(&a));
        g.check(
            recon.max_abs_diff(&a) < 1e-3 * scale * (m as f32).sqrt(),
            "span not preserved",
        );
    });
}

#[test]
fn prop_qr_cgs2_matches_householder() {
    Prop::new("cgs2 == householder+signfix").cases(60).run(|g| {
        let m = g.usize(2, 48);
        let k = g.usize(1, m.min(12));
        let a = g.matrix(m, k, 1.0);
        let q1 = qr_retract(&a);
        let (q2, r) = qr_householder(&a);
        g.check(q1.max_abs_diff(&q2) < 5e-3, "CGS2 and Householder disagree");
        for j in 0..k {
            g.check(r[(j, j)] >= 0.0, "R diagonal must be non-negative");
        }
    });
}

#[test]
fn prop_qr_idempotent() {
    Prop::new("retraction idempotent").cases(60).run(|g| {
        let m = g.usize(2, 64);
        let k = g.usize(1, m.min(16));
        let q0 = qr_retract(&g.matrix(m, k, 1.0));
        let q1 = qr_retract(&q0);
        g.check(q1.max_abs_diff(&q0) < 1e-4, "retract(retract(A)) != retract(A)");
    });
}

#[test]
fn prop_svd_reconstruction_and_ortho() {
    Prop::new("svd reconstructs").cases(40).run(|g| {
        let m = g.usize(2, 28);
        let n = g.usize(2, 28);
        let scale = g.f32(0.2, 3.0);
        let a = g.matrix(m, n, scale);
        let d = svd(&a);
        g.check(
            d.reconstruct().max_abs_diff(&a) < 1e-3 * scale.max(1.0),
            "A != U S V^T",
        );
        g.check(d.u.ortho_error() < 1e-4, "U not orthonormal");
        g.check(d.v.ortho_error() < 1e-4, "V not orthonormal");
        for w in d.s.windows(2) {
            g.check(w[0] >= w[1] - 1e-4, "singular values not sorted");
        }
        g.check(d.s.iter().all(|&x| x >= 0.0), "negative singular value");
    });
}

#[test]
fn prop_svd_energy_rank_bounds() {
    Prop::new("energy rank bounds").cases(60).run(|g| {
        let m = g.usize(3, 24);
        let n = g.usize(3, 24);
        let d = svd(&g.matrix(m, n, 1.0));
        let r50 = d.energy_rank(0.5);
        let r95 = d.energy_rank(0.95);
        g.check(r50 >= 1 && r50 <= r95, "rank not monotone in energy");
        g.check(r95 <= m.min(n), "rank exceeds matrix rank");
    });
}

#[test]
fn prop_spectral_forward_matches_dense() {
    Prop::new("factored fwd == dense fwd").cases(50).run(|g| {
        let m = g.usize(2, 32);
        let n = g.usize(2, 32);
        let k = g.usize(1, m.min(n).min(8));
        let b = g.usize(1, 6);
        let mut rng = sct::util::rng::Rng::new(g.seed);
        let layer = SpectralLinear::init(&mut rng, m, n, k);
        let x = g.matrix(b, m, 1.0);
        let (y, _) = layer.forward(&x);
        let yd = x.matmul(&layer.to_dense());
        g.check(y.max_abs_diff(&yd) < 1e-3, "factored != dense");
    });
}

#[test]
fn prop_layer_grads_have_compact_shapes() {
    Prop::new("no (m,n) gradient exists").cases(40).run(|g| {
        let m = g.usize(2, 40);
        let n = g.usize(2, 40);
        let k = g.usize(1, m.min(n).min(6));
        let b = g.usize(1, 4);
        let mut rng = sct::util::rng::Rng::new(g.seed);
        let layer = SpectralLinear::init(&mut rng, m, n, k);
        let x = g.matrix(b, m, 1.0);
        let dy = g.matrix(b, n, 1.0);
        let (_, cache) = layer.forward(&x);
        let (dx, grads) = layer.backward(&x, &dy, &cache);
        g.check(grads.du.rows == m && grads.du.cols == k, "dU shape");
        g.check(grads.ds.len() == k, "ds shape");
        g.check(grads.dv.rows == n && grads.dv.cols == k, "dV shape");
        g.check(dx.rows == b && dx.cols == m, "dx shape");
    });
}

// ---------------------------------------------------------------------------
// memory model
// ---------------------------------------------------------------------------

#[test]
fn prop_memmodel_invariants() {
    Prop::new("memory model invariants").cases(150).run(|g| {
        let m = g.usize(8, 40000);
        let n = g.usize(8, 40000);
        let k = g.usize(1, 512);
        let l = LayerMemory::fp32(m, n);
        // spectral beats dense iff k(m+n+1) < mn
        let wins = l.spectral_params(k) < l.dense_params();
        g.check(wins == (k * (m + n + 1) < m * n), "break-even point wrong");
        // regime ordering
        g.check(
            l.dense_bytes(TrainRegime::AdamW) > l.dense_bytes(TrainRegime::Sgd),
            "Adam must cost more than SGD",
        );
        g.check(
            l.dense_bytes(TrainRegime::Sgd) > l.dense_bytes(TrainRegime::Frozen),
            "SGD must cost more than frozen",
        );
        // GaLore sits between SCT and dense for small k
        if k * (m + n + 1) < m * n / 4 {
            g.check(
                l.spectral_bytes(k, TrainRegime::AdamW) < l.galore_bytes(k),
                "SCT should beat GaLore",
            );
            g.check(l.galore_bytes(k) < l.dense_bytes(TrainRegime::AdamW), "GaLore < dense");
        }
    });
}

// ---------------------------------------------------------------------------
// data pipeline
// ---------------------------------------------------------------------------

#[test]
fn prop_tokenizer_roundtrip() {
    Prop::new("bpe roundtrip").cases(20).run(|g| {
        let words = ["spectral", "rank", "训练", "q", "factor ", "W=USV^T ", "🤖", "\n"];
        let mut text = String::new();
        let n = g.usize(10, 300);
        for _ in 0..n {
            text.push_str(words[g.usize(0, words.len() - 1)]);
        }
        let vocab = 256 + g.usize(0, 64);
        let tok = Tokenizer::train_bpe(&text, vocab);
        g.check(tok.decode(&tok.encode(&text)) == text, "lossy roundtrip");
        g.check(
            tok.encode(&text).iter().all(|&id| (id as usize) < tok.vocab_size),
            "token id out of range",
        );
    });
}

#[test]
fn prop_dataset_windows_partition_epoch() {
    Prop::new("dataset epoch partition").cases(40).run(|g| {
        let seq1 = g.usize(2, 40);
        let batch = g.usize(1, 6);
        let windows = g.usize(batch, 50);
        let tokens: Vec<i32> = (0..(windows * seq1) as i32).collect();
        let mut ds = Dataset::new(tokens, batch, seq1, g.seed);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..ds.batches_per_epoch() {
            let b = ds.next_batch();
            g.check(b.len() == batch * seq1, "batch size");
            for r in 0..batch {
                let start = b[r * seq1];
                g.check(start as usize % seq1 == 0, "window misaligned");
                g.check(seen.insert(start), "window repeated within epoch");
            }
        }
    });
}

// ---------------------------------------------------------------------------
// serialization
// ---------------------------------------------------------------------------

fn build_json(g: &mut sct::testkit::Gen, depth: usize) -> Json {
    match if depth > 2 { g.usize(0, 3) } else { g.usize(0, 5) } {
        0 => Json::Null,
        1 => Json::Bool(g.bool()),
        2 => Json::Num((g.f32(-1e6, 1e6) as f64 * 100.0).round() / 100.0),
        3 => Json::Str(format!("s{}-\"quote\\slash\n", g.usize(0, 999))),
        4 => Json::Arr((0..g.usize(0, 4)).map(|_| build_json(g, depth + 1)).collect()),
        _ => Json::Obj(
            (0..g.usize(0, 4))
                .map(|i| (format!("k{i}"), build_json(g, depth + 1)))
                .collect(),
        ),
    }
}

#[test]
fn prop_json_roundtrip() {
    Prop::new("json roundtrip").cases(80).run(|g| {
        let v = build_json(g, 0);
        let compact = Json::parse(&v.to_string());
        let pretty = Json::parse(&v.to_string_pretty());
        g.check(compact.as_ref().ok() == Some(&v), "compact roundtrip");
        g.check(pretty.as_ref().ok() == Some(&v), "pretty roundtrip");
    });
}

#[test]
fn prop_checkpoint_roundtrip() {
    Prop::new("checkpoint roundtrip").cases(25).run(|g| {
        let dir = std::env::temp_dir().join(format!("sct_prop_{}", g.seed));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.sct");
        let n_tensors = g.usize(1, 6);
        let mut tensors = Vec::new();
        for i in 0..n_tensors {
            let rows = g.usize(1, 8);
            let cols = g.usize(1, 8);
            let vals = g.vec_f32(rows * cols, 10.0);
            tensors.push(NamedTensor::f32(&format!("t{i}"), vec![rows, cols], &vals));
        }
        let step = g.usize(0, 1_000_000) as u64;
        write_checkpoint(&path, step, &tensors).unwrap();
        let (s2, back) = read_checkpoint(&path).unwrap();
        g.check(s2 == step, "step mismatch");
        g.check(back == tensors, "tensors mismatch");
        std::fs::remove_dir_all(&dir).ok();
    });
}

// ---------------------------------------------------------------------------
// config / schedules
// ---------------------------------------------------------------------------

#[test]
fn prop_schedule_bounds() {
    Prop::new("schedule stays in [floor, peak]").cases(100).run(|g| {
        let peak = g.f32(1e-6, 1.0);
        let floor = peak * g.f32(0.0, 0.9);
        let warmup = g.usize(0, 50);
        let total = warmup + g.usize(1, 500);
        let s = Schedule::WarmupCosine { peak, floor, warmup, total };
        for step in [0, warmup, warmup + 1, total / 2, total, total * 2] {
            let v = s.at(step);
            g.check(v <= peak * 1.0001, "above peak");
            g.check(v >= -1e-9, "negative LR");
            if step >= warmup {
                g.check(v >= floor * 0.999 - 1e-12, "below floor after warmup");
            }
        }
    });
}

#[test]
fn prop_toml_int_roundtrip() {
    Prop::new("toml numeric parse").cases(60).run(|g| {
        let i = g.usize(0, 1_000_000) as i64 - 500_000;
        let doc = parse_toml(&format!("x = {i}\n")).unwrap();
        g.check(doc[""]["x"] == TomlValue::Int(i), "int roundtrip");
    });
}
