//! End-to-end tests of the adaptive-rank subsystem: grow rank mid-training
//! with NO loss discontinuity, keep training through the grown factors,
//! checkpoint a model whose layers carry different ranks, and serve that
//! heterogeneous checkpoint deterministically over HTTP — the full
//! train → transition → checkpoint → serve loop the subsystem exists for.

use sct::coordinator::{run_native, RunConfig};
use sct::data::build_dataset;
use sct::rank::RankPolicyConfig;
use sct::serve::{
    http_post_json, Engine, EngineConfig, SampleOpts, ServeConfig, Server, SpectralModel,
};
use sct::train::{NativeTrainConfig, NativeTrainer};
use sct::util::rng::Rng;

fn train_cfg() -> NativeTrainConfig {
    NativeTrainConfig {
        model: EngineConfig {
            vocab: 256, // byte-level tokenizer
            d_model: 32,
            n_layers: 2,
            n_heads: 4,
            d_ffn: 48,
            rank: 4,
            max_seq: 64,
            tied: true,
        },
        batch: 4,
        seq_len: 24,
        grad_clip: 1.0,
        retract_every: 1,
        weight_decay: 0.0,
    }
}

#[test]
fn grow_mid_training_is_loss_continuous_then_improves() {
    let cfg = train_cfg();
    let (_tok, mut dataset) =
        build_dataset(cfg.model.vocab, cfg.batch, cfg.seq_len + 1, 200_000, 0);
    let mut trainer = NativeTrainer::new(cfg, 0);
    let mut rng = Rng::new(11);

    for _ in 0..25 {
        let (loss, _) = trainer.train_step(&dataset.next_batch(), 2e-3, 2e-3);
        assert!(loss.is_finite());
    }
    let eval_batch = dataset.eval_batch();
    let before = trainer.eval_loss(&eval_batch);

    // the transition: every layer 4 -> 10, at a step boundary
    for layer in 0..2 {
        trainer.set_layer_rank(layer, 10, &mut rng).unwrap();
    }
    assert_eq!(trainer.layer_ranks(), vec![10, 10]);

    // acceptance: eval loss at the transition step matches the
    // pre-transition loss to <= 1e-5 (grow is an exact continuation)
    let at_transition = trainer.eval_loss(&eval_batch);
    assert!(
        (before - at_transition).abs() <= 1e-5,
        "grow must be loss-continuous: {before} vs {at_transition}"
    );
    assert!(trainer.ortho_error() <= 2e-6, "ortho {}", trainer.ortho_error());

    // ...then continues to decrease through the grown factors
    for _ in 0..35 {
        let (loss, _) = trainer.train_step(&dataset.next_batch(), 2e-3, 2e-3);
        assert!(loss.is_finite());
    }
    let post = trainer.eval_loss(&eval_batch);
    assert!(
        post < at_transition,
        "eval loss must keep falling after the grow: {at_transition} -> {post}"
    );
}

#[test]
fn heterogeneous_checkpoint_trains_saves_and_serves_over_http() {
    let cfg = train_cfg();
    let (_tok, mut dataset) =
        build_dataset(cfg.model.vocab, cfg.batch, cfg.seq_len + 1, 120_000, 1);
    let mut trainer = NativeTrainer::new(cfg, 1);
    let mut rng = Rng::new(3);

    // train a few steps, then give each layer a different rank and train on
    for _ in 0..8 {
        trainer.train_step(&dataset.next_batch(), 1e-3, 1e-3);
    }
    trainer.set_layer_rank(0, 9, &mut rng).unwrap();
    trainer.set_layer_rank(1, 2, &mut rng).unwrap(); // grow AND shrink
    assert_eq!(trainer.layer_ranks(), vec![9, 2]);
    for _ in 0..8 {
        let (loss, _) = trainer.train_step(&dataset.next_batch(), 1e-3, 1e-3);
        assert!(loss.is_finite(), "heterogeneous-rank training must stay finite");
    }
    assert!(trainer.ortho_error() <= 2e-6);

    // checkpoint, reload: per-layer ranks survive the .sct roundtrip
    let dir = std::env::temp_dir().join(format!("sct_rank_e2e_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("hetero.sct");
    trainer.save(&ckpt).unwrap();
    let model = SpectralModel::load(&ckpt).unwrap();
    assert_eq!(model.layer_ranks(), vec![9, 2]);

    // engine-level determinism at T=0
    let engine = Engine::new(model);
    let opts = SampleOpts { temperature: 0.0, top_k: 0, seed: 0 };
    let prompt: Vec<i32> = "### Instruction".bytes().map(|b| b as i32).collect();
    let baseline = engine.generate_reencode(&prompt, 12, &opts);
    let mut kv = engine.new_kv(1);
    let slot = kv.alloc().unwrap();
    assert_eq!(
        baseline,
        engine.generate_kv(&prompt, 12, &opts, &mut kv, slot),
        "KV decode must match re-encode on a heterogeneous-rank model"
    );

    // ...and over HTTP through the full server stack
    let serve_cfg = ServeConfig { addr: "127.0.0.1:0".into(), ..ServeConfig::default() };
    let server = Server::start(
        &serve_cfg,
        Engine::new(SpectralModel::load(&ckpt).unwrap()),
        sct::data::Tokenizer::byte_level(),
    )
    .unwrap();
    let req = r#"{"prompt": "adaptive rank", "tokens": 8, "temperature": 0}"#;
    let (code, a) = http_post_json(server.addr, "/v1/generate", req).unwrap();
    assert_eq!(code, 200, "body: {a:?}");
    assert_eq!(a.get("tokens").unwrap().as_arr().unwrap().len(), 8);
    let (_, b) = http_post_json(server.addr, "/v1/generate", req).unwrap();
    assert_eq!(
        a.get("tokens").unwrap(),
        b.get("tokens").unwrap(),
        "heterogeneous-rank checkpoint must serve deterministically at T=0"
    );
    server.stop();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn run_native_with_schedule_emits_events_and_serveable_ranks() {
    // The coordinator path: a [rank] schedule declared in config applies
    // mid-run, shows up in the summary, and the final model reports the
    // scheduled rank everywhere.
    let cfg = RunConfig {
        backend: "native".into(),
        steps: 8,
        eval_every: 4,
        ortho_every: 4,
        corpus_bytes: 60_000,
        batch: 2,
        seq_len: 12,
        native_model: EngineConfig {
            vocab: 256,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ffn: 24,
            rank: 3,
            max_seq: 16,
            tied: true,
        },
        rank_policy: RankPolicyConfig::Schedule(vec![(3, 6)]),
        ..RunConfig::default()
    };
    let (summary, _tracker) = run_native(&cfg, false).unwrap();
    assert_eq!(summary.layer_ranks, vec![6, 6]);
    assert_eq!(summary.rank_events.len(), 2);
    assert!(summary.rank_events.iter().all(|e| e.step == 3 && e.from == 3 && e.to == 6));
    assert!(summary.ortho_error.unwrap() <= 2e-6);
    assert!(summary.final_loss_smoothed.is_finite());
}
