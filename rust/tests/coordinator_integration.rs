//! Coordinator-level integration tests: trainer loop, checkpoint
//! resume-bit-exactness, per-component LRs through the real artifacts, and
//! the pallas-kernel-path preset. Skip cleanly when artifacts are missing.

// Trainer/Session need PJRT execution.
#![cfg(feature = "pjrt")]

use sct::checkpoint::CheckpointManager;
use sct::coordinator::{LrPlan, RunConfig, Trainer};
use sct::runtime::{Manifest, Session};

fn artifacts_root() -> Option<std::path::PathBuf> {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    root.join("manifest.json").exists().then_some(root)
}

fn base_cfg(preset: &str, steps: usize) -> Option<RunConfig> {
    let root = artifacts_root()?;
    let mut cfg = RunConfig::default();
    cfg.artifacts_root = root.to_str().unwrap().to_string();
    cfg.preset = preset.into();
    cfg.steps = steps;
    cfg.corpus_bytes = 300 << 10;
    cfg.eval_every = 0;
    cfg.ortho_every = 0;
    Some(cfg)
}

#[test]
fn trainer_loop_runs_and_learns() {
    let Some(mut cfg) = base_cfg("tiny_r8", 30) else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    cfg.lr_plan = LrPlan::split(1e-3, 5e-3);
    cfg.ortho_every = 10;
    let mut t = Trainer::new(cfg).unwrap();
    let s = t.run().unwrap();
    assert_eq!(s.steps, 30);
    assert!(s.final_loss_smoothed < s.losses[0], "{} -> {}", s.losses[0], s.final_loss_smoothed);
    assert!(s.ortho_error.unwrap() < 2e-6);
    assert!(s.mean_step_s > 0.0);
}

#[test]
fn chunked_and_unchunked_agree() {
    let Some(cfg) = base_cfg("tiny_r8", 20) else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut c1 = cfg.clone();
    c1.chunked = true;
    let mut c2 = cfg;
    c2.chunked = false;
    let s1 = Trainer::new(c1).unwrap().run().unwrap();
    let s2 = Trainer::new(c2).unwrap().run().unwrap();
    // identical data (same seed) + identical math -> near-identical losses
    assert_eq!(s1.losses.len(), s2.losses.len());
    for (i, (a, b)) in s1.losses.iter().zip(&s2.losses).enumerate() {
        assert!((a - b).abs() < 1e-4 * b.abs().max(1.0), "step {i}: {a} vs {b}");
    }
}

#[test]
fn checkpoint_resume_is_bit_exact() {
    let Some(root) = artifacts_root() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let dir = std::env::temp_dir().join(format!("sct_resume_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();

    // Session A: train 10 steps, checkpoint, train 10 more.
    let toks = |seed: i64, n: usize| -> Vec<i32> {
        (0..n).map(|i| ((i as i64 * 13 + seed * 31) % 256) as i32).collect()
    };
    let mut a = Session::open(&root, "tiny_r8").unwrap();
    a.init(11).unwrap();
    let n = a.preset.tokens_spec().unwrap().elements();
    for i in 0..10 {
        a.train_step(&toks(i, n), 1e-3, 1e-3).unwrap();
    }
    let mgr = CheckpointManager::new(&dir, 2).unwrap();
    mgr.save(&a).unwrap();
    let mut losses_a = Vec::new();
    for i in 10..20 {
        losses_a.push(a.train_step(&toks(i, n), 1e-3, 1e-3).unwrap());
    }

    // Session B: restore the checkpoint, train the same 10 steps.
    let mut b = Session::open(&root, "tiny_r8").unwrap();
    let step = mgr.restore_latest(&mut b).unwrap();
    assert_eq!(step, 10);
    let mut losses_b = Vec::new();
    for i in 10..20 {
        losses_b.push(b.train_step(&toks(i, n), 1e-3, 1e-3).unwrap());
    }
    assert_eq!(losses_a, losses_b, "resume must be bit-exact");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn split_lr_freezes_dense_when_zero() {
    // lr_dense = 0: attention/embeddings must not move; spectral must.
    let Some(root) = artifacts_root() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut s = Session::open(&root, "tiny_r8").unwrap();
    s.init(3).unwrap();
    let (_, wq_before) = s.tensor_f32("params/layers/0/attn/wq").unwrap();
    let (_, u_before) = s.tensor_f32("params/layers/0/mlp/gate/u").unwrap();
    let n = s.preset.tokens_spec().unwrap().elements();
    let toks: Vec<i32> = (0..n).map(|i| (i % 256) as i32).collect();
    s.train_step(&toks, 0.0, 1e-3).unwrap();
    let (_, wq_after) = s.tensor_f32("params/layers/0/attn/wq").unwrap();
    let (_, u_after) = s.tensor_f32("params/layers/0/mlp/gate/u").unwrap();
    assert_eq!(wq_before, wq_after, "dense params moved with lr_dense=0");
    assert_ne!(u_before, u_after, "spectral factors should move");
}

#[test]
fn pallas_preset_forward_matches_ref_preset() {
    // The pallas-kernel-lowered HLO must produce the same forward numbers
    // as the jnp-oracle path, run END TO END through the rust runtime.
    let Some(root) = artifacts_root() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let m = Manifest::load(&root).unwrap();
    if !m.presets.contains_key("tiny_r8_pallas") {
        eprintln!("skipping: pallas preset not exported");
        return;
    }
    let mut a = Session::open(&root, "tiny_r8").unwrap();
    let mut b = Session::open(&root, "tiny_r8_pallas").unwrap();
    a.init(5).unwrap();
    b.init(5).unwrap(); // same init graph -> identical params

    let fwd = a.preset.artifact("forward").unwrap();
    let ti = fwd.input_index("tokens").unwrap();
    let n = fwd.inputs[ti].elements();
    let toks: Vec<i32> = (0..n).map(|i| ((i * 7) % 256) as i32).collect();

    let (shape_a, logits_a) = a.forward(&toks).unwrap();
    let (shape_b, logits_b) = b.forward(&toks).unwrap();
    assert_eq!(shape_a, shape_b);
    let max = logits_a.iter().map(|x| x.abs()).fold(0.0f32, f32::max);
    for (i, (x, y)) in logits_a.iter().zip(&logits_b).enumerate() {
        assert!(
            (x - y).abs() < 1e-4 * max.max(1.0),
            "logit {i}: ref {x} vs pallas {y}"
        );
    }
}

#[test]
fn trainer_rejects_missing_preset() {
    let Some(mut cfg) = base_cfg("tiny_r8", 1) else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    cfg.preset = "no_such_preset".into();
    assert!(Trainer::new(cfg).is_err());
}
