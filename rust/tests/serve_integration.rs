//! End-to-end tests of the `serve` subsystem: a real TCP server, concurrent
//! HTTP clients, and the KV-cache-vs-re-encode equivalence through the
//! public API. Pure std — no PJRT, no artifacts.

use sct::data::Tokenizer;
use sct::serve::{
    http_get_json, http_post_json, Engine, EngineConfig, SampleOpts, ServeConfig, Server,
    SpectralModel,
};

fn tiny_engine(seed: u64) -> Engine {
    let cfg = EngineConfig {
        vocab: 256,
        d_model: 48,
        n_layers: 2,
        n_heads: 4,
        d_ffn: 96,
        rank: 6,
        max_seq: 64,
    };
    Engine::new(SpectralModel::init(cfg, seed))
}

fn start_server(slots: usize, queue: usize) -> Server {
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        slots,
        queue_depth: queue,
        max_new_default: 8,
    };
    Server::start(&cfg, tiny_engine(42), Tokenizer::byte_level()).unwrap()
}

#[test]
fn eight_concurrent_requests_all_complete() {
    // The acceptance workload: >= 8 concurrent generation requests against
    // a running server, all of which must complete.
    let srv = start_server(4, 16);
    let addr = srv.addr;
    let handles: Vec<_> = (0..8)
        .map(|i| {
            std::thread::spawn(move || {
                let body = format!(
                    r#"{{"prompt": "request number {i}", "tokens": 10, "temperature": 0.7, "seed": {i}}}"#
                );
                http_post_json(addr, "/v1/generate", &body).unwrap()
            })
        })
        .collect();
    for h in handles {
        let (code, resp) = h.join().unwrap();
        assert_eq!(code, 200, "resp: {resp:?}");
        assert_eq!(resp.get("tokens").unwrap().as_arr().unwrap().len(), 10);
        assert!(resp.get("decode_ms").unwrap().as_f64().unwrap() > 0.0);
    }
    let (_, stats) = http_get_json(addr, "/v1/stats").unwrap();
    assert_eq!(stats.get("completed").unwrap().as_i64().unwrap(), 8);
    assert_eq!(stats.get("tokens_out").unwrap().as_i64().unwrap(), 80);
    srv.stop();
}

#[test]
fn served_greedy_output_matches_reencode_baseline() {
    // Token-identical KV-cached decode vs the full re-encode baseline, at
    // temperature 0, through the whole HTTP + batcher + engine stack.
    let srv = start_server(2, 8);
    let prompt = "spectral compact training";
    let (code, resp) = http_post_json(
        srv.addr,
        "/v1/generate",
        &format!(r#"{{"prompt": "{prompt}", "tokens": 12, "temperature": 0}}"#),
    )
    .unwrap();
    assert_eq!(code, 200);
    let served: Vec<i32> = resp
        .get("tokens")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_i64().unwrap() as i32)
        .collect();

    // Same model seed, same tokenization, re-encode decoder.
    let engine = tiny_engine(42);
    let ids = Tokenizer::byte_level().encode(prompt);
    let opts = SampleOpts { temperature: 0.0, top_k: 0, seed: 0 };
    let baseline = engine.generate_reencode(&ids, 12, &opts);
    assert_eq!(served, baseline, "served KV decode must equal re-encode baseline");
    srv.stop();
}

#[test]
fn healthz_reports_configuration() {
    let srv = start_server(3, 5);
    let (code, body) = http_get_json(srv.addr, "/healthz").unwrap();
    assert_eq!(code, 200);
    assert_eq!(body.get("status").unwrap().as_str().unwrap(), "ok");
    assert_eq!(body.get("slots").unwrap().as_usize().unwrap(), 3);
    assert_eq!(body.get("queue_depth").unwrap().as_usize().unwrap(), 5);
    srv.stop();
}

#[test]
fn overload_returns_503_not_a_hang() {
    // 1 slot + depth-1 queue, long generations: some of a burst of clients
    // must be shed with 503; the rest complete.
    let srv = start_server(1, 1);
    let addr = srv.addr;
    let handles: Vec<_> = (0..6)
        .map(|i| {
            std::thread::spawn(move || {
                let body = format!(
                    r#"{{"prompt": "burst {i}", "tokens": 30, "temperature": 0}}"#
                );
                http_post_json(addr, "/v1/generate", &body).unwrap().0
            })
        })
        .collect();
    let codes: Vec<u16> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert!(codes.iter().all(|&c| c == 200 || c == 503), "codes: {codes:?}");
    assert!(codes.contains(&200), "at least one request must be served: {codes:?}");
    srv.stop();
}
