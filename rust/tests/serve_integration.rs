//! End-to-end tests of the `serve` subsystem: a real TCP server, concurrent
//! HTTP clients, SSE streaming vs one-shot equivalence, chunked-prefill
//! fairness, and the KV-cache-vs-re-encode equivalence through the public
//! API. Pure std — no PJRT, no artifacts.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::mpsc::RecvTimeoutError;
use std::time::Duration;

use sct::data::Tokenizer;
use sct::serve::{
    http_get_json, http_post_json, http_post_sse, BatchConfig, Batcher, Engine, EngineConfig,
    Request, SampleOpts, ServeConfig, Server, SpectralModel, StreamEvent,
};

fn tiny_engine(seed: u64) -> Engine {
    let cfg = EngineConfig {
        vocab: 256,
        d_model: 48,
        n_layers: 2,
        n_heads: 4,
        d_ffn: 96,
        rank: 6,
        max_seq: 64,
        tied: true,
    };
    Engine::new(SpectralModel::init(cfg, seed))
}

fn start_server(slots: usize, queue: usize) -> Server {
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        slots,
        queue_depth: queue,
        max_new_default: 8,
        ..ServeConfig::default()
    };
    Server::start(&cfg, tiny_engine(42), Tokenizer::byte_level()).unwrap()
}

#[test]
fn eight_concurrent_requests_all_complete() {
    // The acceptance workload: >= 8 concurrent generation requests against
    // a running server, all of which must complete.
    let srv = start_server(4, 16);
    let addr = srv.addr;
    let handles: Vec<_> = (0..8)
        .map(|i| {
            std::thread::spawn(move || {
                let body = format!(
                    r#"{{"prompt": "request number {i}", "tokens": 10, "temperature": 0.7, "seed": {i}}}"#
                );
                http_post_json(addr, "/v1/generate", &body).unwrap()
            })
        })
        .collect();
    for h in handles {
        let (code, resp) = h.join().unwrap();
        assert_eq!(code, 200, "resp: {resp:?}");
        assert_eq!(resp.get("tokens").unwrap().as_arr().unwrap().len(), 10);
        assert!(resp.get("decode_ms").unwrap().as_f64().unwrap() > 0.0);
    }
    let (_, stats) = http_get_json(addr, "/v1/stats").unwrap();
    assert_eq!(stats.get("completed").unwrap().as_i64().unwrap(), 8);
    assert_eq!(stats.get("tokens_out").unwrap().as_i64().unwrap(), 80);
    srv.stop();
}

#[test]
fn served_greedy_output_matches_reencode_baseline() {
    // Token-identical KV-cached decode vs the full re-encode baseline, at
    // temperature 0, through the whole HTTP + batcher + engine stack.
    let srv = start_server(2, 8);
    let prompt = "spectral compact training";
    let (code, resp) = http_post_json(
        srv.addr,
        "/v1/generate",
        &format!(r#"{{"prompt": "{prompt}", "tokens": 12, "temperature": 0}}"#),
    )
    .unwrap();
    assert_eq!(code, 200);
    let served: Vec<i32> = resp
        .get("tokens")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_i64().unwrap() as i32)
        .collect();

    // Same model seed, same tokenization, re-encode decoder.
    let engine = tiny_engine(42);
    let ids = Tokenizer::byte_level().encode(prompt);
    let opts = SampleOpts { temperature: 0.0, top_k: 0, seed: 0 };
    let baseline = engine.generate_reencode(&ids, 12, &opts);
    assert_eq!(served, baseline, "served KV decode must equal re-encode baseline");
    srv.stop();
}

#[test]
fn healthz_reports_configuration() {
    let srv = start_server(3, 5);
    let (code, body) = http_get_json(srv.addr, "/healthz").unwrap();
    assert_eq!(code, 200);
    assert_eq!(body.get("status").unwrap().as_str().unwrap(), "ok");
    assert_eq!(body.get("slots").unwrap().as_usize().unwrap(), 3);
    assert_eq!(body.get("queue_depth").unwrap().as_usize().unwrap(), 5);
    srv.stop();
}

#[test]
fn overload_returns_503_not_a_hang() {
    // 1 slot + depth-1 queue, long generations: some of a burst of clients
    // must be shed with 503; the rest complete.
    let srv = start_server(1, 1);
    let addr = srv.addr;
    let handles: Vec<_> = (0..6)
        .map(|i| {
            std::thread::spawn(move || {
                let body = format!(
                    r#"{{"prompt": "burst {i}", "tokens": 30, "temperature": 0}}"#
                );
                http_post_json(addr, "/v1/generate", &body).unwrap().0
            })
        })
        .collect();
    let codes: Vec<u16> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert!(codes.iter().all(|&c| c == 200 || c == 503), "codes: {codes:?}");
    assert!(codes.contains(&200), "at least one request must be served: {codes:?}");
    srv.stop();
}

#[test]
fn sse_frames_concatenate_to_the_nonstreaming_token_sequence() {
    // The streaming acceptance criterion: SSE frames arrive incrementally
    // (one per token, each in its own timestamped HTTP chunk) and their
    // token ids concatenate to exactly the one-shot output at temperature 0.
    let srv = start_server(2, 8);
    let body = r#"{"prompt": "stream equivalence probe", "tokens": 16, "temperature": 0}"#;
    let (code, oneshot) = http_post_json(srv.addr, "/v1/generate", body).unwrap();
    assert_eq!(code, 200);
    let expected: Vec<i64> = oneshot
        .get("tokens")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_i64().unwrap())
        .collect();

    let streaming_body =
        r#"{"prompt": "stream equivalence probe", "tokens": 16, "temperature": 0, "stream": true}"#;
    let (code, frames) = http_post_sse(srv.addr, "/v1/generate", streaming_body).unwrap();
    assert_eq!(code, 200);
    assert_eq!(frames.len(), 17, "16 token frames + 1 usage frame");

    let token_frames = &frames[..16];
    let streamed: Vec<i64> =
        token_frames.iter().map(|f| f.data.get("token").unwrap().as_i64().unwrap()).collect();
    assert_eq!(streamed, expected, "SSE tokens must equal the one-shot sequence");
    for (i, f) in token_frames.iter().enumerate() {
        assert_eq!(f.data.get("index").unwrap().as_usize().unwrap(), i);
    }
    // incremental arrival: client-side timestamps are monotone and the
    // first token landed before the stream finished
    for w in frames.windows(2) {
        assert!(w[0].at_s <= w[1].at_s, "frame timestamps must be monotone");
    }
    let done = &frames[16].data;
    assert!(done.get("done").unwrap().as_bool().unwrap());
    assert_eq!(
        done.get("completion").unwrap().as_str().unwrap(),
        oneshot.get("completion").unwrap().as_str().unwrap(),
        "streamed completion text must equal the one-shot text"
    );
    assert!(done.get("ttft_ms").unwrap().as_f64().unwrap() > 0.0);
    srv.stop();
}

#[test]
fn keep_alive_connection_survives_an_sse_stream() {
    // Streaming and keep-alive compose: after the terminating zero-length
    // chunk, the same connection serves a further request.
    let srv = start_server(2, 8);
    let mut conn = TcpStream::connect(srv.addr).unwrap();
    conn.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let body = r#"{"prompt": "keep me", "tokens": 4, "temperature": 0, "stream": true}"#;
    let raw = format!(
        "POST /v1/generate HTTP/1.1\r\nHost: sct\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    conn.write_all(raw.as_bytes()).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());

    // response head
    let mut status = String::new();
    reader.read_line(&mut status).unwrap();
    assert!(status.contains("200"), "status line: {status:?}");
    let mut chunked = false;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h).unwrap();
        if h.trim().is_empty() {
            break;
        }
        let h = h.to_ascii_lowercase();
        chunked |= h.starts_with("transfer-encoding") && h.contains("chunked");
    }
    assert!(chunked, "SSE response must be chunked");
    // drain chunks to the terminator
    let mut data_frames = 0;
    loop {
        let mut szline = String::new();
        reader.read_line(&mut szline).unwrap();
        let sz = usize::from_str_radix(szline.trim(), 16).unwrap();
        let mut chunk = vec![0u8; sz + 2];
        reader.read_exact(&mut chunk).unwrap();
        if sz == 0 {
            break;
        }
        if chunk.starts_with(b"data: ") {
            data_frames += 1;
        }
    }
    assert_eq!(data_frames, 5, "4 token frames + 1 usage frame");

    // the connection is still usable: plain request over the same socket
    conn.write_all(b"GET /healthz HTTP/1.1\r\nHost: sct\r\n\r\n").unwrap();
    let mut status = String::new();
    reader.read_line(&mut status).unwrap();
    assert!(status.contains("200"), "healthz after SSE: {status:?}");
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h).unwrap();
        if h.trim().is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().unwrap();
            }
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).unwrap();
    assert!(String::from_utf8_lossy(&body).contains("\"ok\""));
    srv.stop();
}

#[test]
fn chunked_prefill_keeps_active_decodes_responsive() {
    // The fairness acceptance criterion: while a >=512-token prompt is
    // being admitted, an already-decoding sequence keeps producing tokens.
    // With a prefill budget of 8 tokens/step, absorbing the 511 prefill
    // positions takes ~64 scheduler steps, each of which also decodes one
    // token of the active sequence — so many tokens of A must land between
    // B's submission and B's first token. (Inline prefill would admit B in
    // one stalled step: A would see at most a couple of tokens in between.)
    let cfg = EngineConfig {
        vocab: 50,
        d_model: 32,
        n_layers: 2,
        n_heads: 4,
        d_ffn: 48,
        rank: 4,
        max_seq: 640,
        tied: true,
    };
    let b = Batcher::spawn_with(
        Engine::new(SpectralModel::init(cfg, 0)),
        BatchConfig { slots: 2, queue_depth: 4, prefill_chunk: 8 },
    );
    let greedy = SampleOpts { temperature: 0.0, top_k: 0, seed: 0 };

    // A: short prompt, long generation — the active decode.
    let rxa = b
        .submit_streaming(Request {
            prompt: vec![1, 2, 3],
            max_new: 200,
            opts: greedy.clone(),
            stop: vec![],
        })
        .unwrap();
    match rxa.recv_timeout(Duration::from_secs(30)) {
        Ok(StreamEvent::Token(_)) => {} // A is admitted and decoding
        other => panic!("expected A's first token, got {other:?}"),
    }

    // B: 512-token prompt.
    let long_prompt: Vec<i32> = (0..512).map(|i| i % 50).collect();
    let rxb = b
        .submit_streaming(Request { prompt: long_prompt, max_new: 4, opts: greedy, stop: vec![] })
        .unwrap();

    let mut a_tokens_during_admission = 0usize;
    loop {
        match rxb.try_recv() {
            Ok(StreamEvent::Token(_)) | Ok(StreamEvent::Done(_)) => break,
            Err(_) => {}
        }
        match rxa.recv_timeout(Duration::from_secs(30)) {
            Ok(StreamEvent::Token(_)) => a_tokens_during_admission += 1,
            Ok(StreamEvent::Done(_)) => panic!("A exhausted its 200-token budget before B decoded"),
            Err(RecvTimeoutError::Timeout) => panic!("scheduler stalled"),
            Err(RecvTimeoutError::Disconnected) => panic!("batcher died"),
        }
    }
    assert!(
        a_tokens_during_admission >= 16,
        "active decode made only {a_tokens_during_admission} steps of progress while the \
         512-token prompt was admitted — prefill is stalling the batch"
    );
    assert!(b.stats().prefill_tokens() >= 511);
    drop(rxa);
    drop(rxb);
}
