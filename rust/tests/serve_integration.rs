//! End-to-end tests of the `serve` subsystem: a real TCP server, concurrent
//! HTTP clients, SSE streaming vs one-shot equivalence, chunked-prefill
//! fairness, sharded (multi-worker) serving determinism, the uniform error
//! envelope on every failure route, and the KV-cache-vs-re-encode
//! equivalence through the public API. Pure std — no PJRT, no artifacts.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::mpsc::RecvTimeoutError;
use std::time::Duration;

use sct::data::Tokenizer;
use sct::serve::{
    http_get_json, http_get_text, http_post_json, http_post_sse, BatchConfig, Batcher, Engine,
    EngineConfig, Request, SampleOpts, ServeConfig, Server, SpectralModel, StreamEvent,
};
use sct::util::json::Json;

fn tiny_engine(seed: u64) -> Engine {
    let cfg = EngineConfig {
        vocab: 256,
        d_model: 48,
        n_layers: 2,
        n_heads: 4,
        d_ffn: 96,
        rank: 6,
        max_seq: 64,
        tied: true,
    };
    Engine::new(SpectralModel::init(cfg, seed))
}

fn start_server_workers(workers: usize, slots: usize, queue: usize) -> Server {
    // `workers` is explicit (not `..default()`) so a stray SCT_WORKERS in
    // the test environment cannot change the topology under test.
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        workers,
        slots,
        queue_depth: queue,
        max_new_default: 8,
        ..ServeConfig::default()
    };
    Server::start(&cfg, tiny_engine(42), Tokenizer::byte_level()).unwrap()
}

fn start_server(slots: usize, queue: usize) -> Server {
    start_server_workers(1, slots, queue)
}

#[test]
fn eight_concurrent_requests_all_complete() {
    // The acceptance workload: >= 8 concurrent generation requests against
    // a running server, all of which must complete.
    let srv = start_server(4, 16);
    let addr = srv.addr;
    let handles: Vec<_> = (0..8)
        .map(|i| {
            std::thread::spawn(move || {
                let body = format!(
                    r#"{{"prompt": "request number {i}", "tokens": 10, "temperature": 0.7, "seed": {i}}}"#
                );
                http_post_json(addr, "/v1/generate", &body).unwrap()
            })
        })
        .collect();
    for h in handles {
        let (code, resp) = h.join().unwrap();
        assert_eq!(code, 200, "resp: {resp:?}");
        assert_eq!(resp.get("tokens").unwrap().as_arr().unwrap().len(), 10);
        assert!(resp.get("decode_ms").unwrap().as_f64().unwrap() > 0.0);
    }
    let (_, stats) = http_get_json(addr, "/v1/stats").unwrap();
    assert_eq!(stats.get("completed").unwrap().as_i64().unwrap(), 8);
    assert_eq!(stats.get("tokens_out").unwrap().as_i64().unwrap(), 80);
    srv.stop();
}

#[test]
fn served_greedy_output_matches_reencode_baseline() {
    // Token-identical KV-cached decode vs the full re-encode baseline, at
    // temperature 0, through the whole HTTP + batcher + engine stack.
    let srv = start_server(2, 8);
    let prompt = "spectral compact training";
    let (code, resp) = http_post_json(
        srv.addr,
        "/v1/generate",
        &format!(r#"{{"prompt": "{prompt}", "tokens": 12, "temperature": 0}}"#),
    )
    .unwrap();
    assert_eq!(code, 200);
    let served: Vec<i32> = resp
        .get("tokens")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_i64().unwrap() as i32)
        .collect();

    // Same model seed, same tokenization, re-encode decoder.
    let engine = tiny_engine(42);
    let ids = Tokenizer::byte_level().encode(prompt);
    let opts = SampleOpts { temperature: 0.0, top_k: 0, seed: 0 };
    let baseline = engine.generate_reencode(&ids, 12, &opts);
    assert_eq!(served, baseline, "served KV decode must equal re-encode baseline");
    srv.stop();
}

#[test]
fn healthz_reports_configuration() {
    let srv = start_server(3, 5);
    let (code, body) = http_get_json(srv.addr, "/healthz").unwrap();
    assert_eq!(code, 200);
    assert_eq!(body.get("status").unwrap().as_str().unwrap(), "ok");
    assert_eq!(body.get("slots").unwrap().as_usize().unwrap(), 3);
    assert_eq!(body.get("queue_depth").unwrap().as_usize().unwrap(), 5);
    srv.stop();
}

#[test]
fn overload_returns_503_not_a_hang() {
    // 1 slot + depth-1 queue, long generations: some of a burst of clients
    // must be shed with 503; the rest complete.
    let srv = start_server(1, 1);
    let addr = srv.addr;
    let handles: Vec<_> = (0..6)
        .map(|i| {
            std::thread::spawn(move || {
                let body = format!(
                    r#"{{"prompt": "burst {i}", "tokens": 30, "temperature": 0}}"#
                );
                http_post_json(addr, "/v1/generate", &body).unwrap()
            })
        })
        .collect();
    let responses: Vec<(u16, Json)> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let codes: Vec<u16> = responses.iter().map(|r| r.0).collect();
    assert!(codes.iter().all(|&c| c == 200 || c == 503), "codes: {codes:?}");
    assert!(codes.contains(&200), "at least one request must be served: {codes:?}");
    for (code, body) in &responses {
        if *code == 503 {
            assert_envelope(body, "queue_full");
        }
    }
    srv.stop();
}

/// Assert a response body is a well-formed error envelope with this code.
fn assert_envelope(body: &Json, code: &str) {
    assert_eq!(body.get("code").unwrap().as_str().unwrap(), code, "body: {body:?}");
    assert!(!body.get("message").unwrap().as_str().unwrap().is_empty());
    assert!(body.get("request_id").unwrap().as_i64().unwrap() > 0, "errors carry request ids");
}

#[test]
fn sse_frames_concatenate_to_the_nonstreaming_token_sequence() {
    // The streaming acceptance criterion: SSE frames arrive incrementally
    // (one per token, each in its own timestamped HTTP chunk) and their
    // token ids concatenate to exactly the one-shot output at temperature 0.
    let srv = start_server(2, 8);
    let body = r#"{"prompt": "stream equivalence probe", "tokens": 16, "temperature": 0}"#;
    let (code, oneshot) = http_post_json(srv.addr, "/v1/generate", body).unwrap();
    assert_eq!(code, 200);
    let expected: Vec<i64> = oneshot
        .get("tokens")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_i64().unwrap())
        .collect();

    let streaming_body =
        r#"{"prompt": "stream equivalence probe", "tokens": 16, "temperature": 0, "stream": true}"#;
    let (code, frames) = http_post_sse(srv.addr, "/v1/generate", streaming_body).unwrap();
    assert_eq!(code, 200);
    assert_eq!(frames.len(), 17, "16 token frames + 1 usage frame");

    let token_frames = &frames[..16];
    let streamed: Vec<i64> =
        token_frames.iter().map(|f| f.data.get("token").unwrap().as_i64().unwrap()).collect();
    assert_eq!(streamed, expected, "SSE tokens must equal the one-shot sequence");
    for (i, f) in token_frames.iter().enumerate() {
        assert_eq!(f.data.get("index").unwrap().as_usize().unwrap(), i);
    }
    // incremental arrival: client-side timestamps are monotone and the
    // first token landed before the stream finished
    for w in frames.windows(2) {
        assert!(w[0].at_s <= w[1].at_s, "frame timestamps must be monotone");
    }
    let done = &frames[16].data;
    assert!(done.get("done").unwrap().as_bool().unwrap());
    assert_eq!(
        done.get("completion").unwrap().as_str().unwrap(),
        oneshot.get("completion").unwrap().as_str().unwrap(),
        "streamed completion text must equal the one-shot text"
    );
    assert!(done.get("ttft_ms").unwrap().as_f64().unwrap() > 0.0);
    srv.stop();
}

#[test]
fn keep_alive_connection_survives_an_sse_stream() {
    // Streaming and keep-alive compose: after the terminating zero-length
    // chunk, the same connection serves a further request.
    let srv = start_server(2, 8);
    let mut conn = TcpStream::connect(srv.addr).unwrap();
    conn.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let body = r#"{"prompt": "keep me", "tokens": 4, "temperature": 0, "stream": true}"#;
    let raw = format!(
        "POST /v1/generate HTTP/1.1\r\nHost: sct\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    conn.write_all(raw.as_bytes()).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());

    // response head
    let mut status = String::new();
    reader.read_line(&mut status).unwrap();
    assert!(status.contains("200"), "status line: {status:?}");
    let mut chunked = false;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h).unwrap();
        if h.trim().is_empty() {
            break;
        }
        let h = h.to_ascii_lowercase();
        chunked |= h.starts_with("transfer-encoding") && h.contains("chunked");
    }
    assert!(chunked, "SSE response must be chunked");
    // drain chunks to the terminator
    let mut data_frames = 0;
    loop {
        let mut szline = String::new();
        reader.read_line(&mut szline).unwrap();
        let sz = usize::from_str_radix(szline.trim(), 16).unwrap();
        let mut chunk = vec![0u8; sz + 2];
        reader.read_exact(&mut chunk).unwrap();
        if sz == 0 {
            break;
        }
        if chunk.starts_with(b"data: ") {
            data_frames += 1;
        }
    }
    assert_eq!(data_frames, 5, "4 token frames + 1 usage frame");

    // the connection is still usable: plain request over the same socket
    conn.write_all(b"GET /healthz HTTP/1.1\r\nHost: sct\r\n\r\n").unwrap();
    let mut status = String::new();
    reader.read_line(&mut status).unwrap();
    assert!(status.contains("200"), "healthz after SSE: {status:?}");
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h).unwrap();
        if h.trim().is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().unwrap();
            }
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).unwrap();
    assert!(String::from_utf8_lossy(&body).contains("\"ok\""));
    srv.stop();
}

#[test]
fn chunked_prefill_keeps_active_decodes_responsive() {
    // The fairness acceptance criterion: while a >=512-token prompt is
    // being admitted, an already-decoding sequence keeps producing tokens.
    // With a prefill budget of 8 tokens/step, absorbing the 511 prefill
    // positions takes ~64 scheduler steps, each of which also decodes one
    // token of the active sequence — so many tokens of A must land between
    // B's submission and B's first token. (Inline prefill would admit B in
    // one stalled step: A would see at most a couple of tokens in between.)
    let cfg = EngineConfig {
        vocab: 50,
        d_model: 32,
        n_layers: 2,
        n_heads: 4,
        d_ffn: 48,
        rank: 4,
        max_seq: 640,
        tied: true,
    };
    let b = Batcher::spawn_with(
        Engine::new(SpectralModel::init(cfg, 0)),
        BatchConfig { slots: 2, queue_depth: 4, prefill_chunk: 8, ..BatchConfig::default() },
    );
    let greedy = SampleOpts { temperature: 0.0, top_k: 0, seed: 0 };

    // A: short prompt, long generation — the active decode.
    let rxa = b
        .submit_streaming(Request {
            prompt: vec![1, 2, 3],
            max_new: 200,
            opts: greedy.clone(),
            stop: vec![],
        })
        .unwrap();
    match rxa.recv_timeout(Duration::from_secs(30)) {
        Ok(StreamEvent::Token(_)) => {} // A is admitted and decoding
        other => panic!("expected A's first token, got {other:?}"),
    }

    // B: 512-token prompt.
    let long_prompt: Vec<i32> = (0..512).map(|i| i % 50).collect();
    let rxb = b
        .submit_streaming(Request { prompt: long_prompt, max_new: 4, opts: greedy, stop: vec![] })
        .unwrap();

    let mut a_tokens_during_admission = 0usize;
    loop {
        match rxb.try_recv() {
            Ok(StreamEvent::Token(_)) | Ok(StreamEvent::Done(_)) => break,
            Err(_) => {}
        }
        match rxa.recv_timeout(Duration::from_secs(30)) {
            Ok(StreamEvent::Token(_)) => a_tokens_during_admission += 1,
            Ok(StreamEvent::Done(_)) => panic!("A exhausted its 200-token budget before B decoded"),
            Err(RecvTimeoutError::Timeout) => panic!("scheduler stalled"),
            Err(RecvTimeoutError::Disconnected) => panic!("batcher died"),
        }
    }
    assert!(
        a_tokens_during_admission >= 16,
        "active decode made only {a_tokens_during_admission} steps of progress while the \
         512-token prompt was admitted — prefill is stalling the batch"
    );
    assert!(b.stats().prefill_tokens() >= 511);
    drop(rxa);
    drop(rxb);
}

#[test]
fn t0_output_is_byte_identical_at_workers_1_and_2() {
    // The sharding acceptance criterion, end to end over HTTP: the same
    // fixed prompt at temperature 0 returns byte-identical completion text
    // (and token ids) from a 1-worker and a 2-worker server, for every
    // request of a concurrent burst — placement must be invisible in the
    // output.
    let body = r#"{"prompt": "sharding determinism probe", "tokens": 12, "temperature": 0}"#;

    let solo = start_server_workers(1, 2, 16);
    let (code, baseline) = http_post_json(solo.addr, "/v1/generate", body).unwrap();
    assert_eq!(code, 200, "baseline: {baseline:?}");
    solo.stop();
    let base_text = baseline.get("completion").unwrap().as_str().unwrap().to_string();
    let base_tokens = baseline.get("tokens").unwrap().clone();

    let sharded = start_server_workers(2, 2, 16);
    let addr = sharded.addr;
    let handles: Vec<_> = (0..8)
        .map(|_| {
            std::thread::spawn(move || {
                http_post_json(
                    addr,
                    "/v1/generate",
                    r#"{"prompt": "sharding determinism probe", "tokens": 12, "temperature": 0}"#,
                )
                .unwrap()
            })
        })
        .collect();
    for h in handles {
        let (code, resp) = h.join().unwrap();
        assert_eq!(code, 200, "resp: {resp:?}");
        assert_eq!(
            resp.get("completion").unwrap().as_str().unwrap(),
            base_text,
            "completion text must not depend on worker count or placement"
        );
        assert_eq!(resp.get("tokens").unwrap(), &base_tokens);
        let worker = resp.get("worker").unwrap().as_i64().unwrap();
        assert!((0..2).contains(&worker), "worker index on a 2-worker gateway: {worker}");
    }

    // the versioned stats document accounts for every request, per worker
    let (code, stats) = http_get_json(addr, "/v1/stats").unwrap();
    assert_eq!(code, 200);
    assert_eq!(stats.get("admitted").unwrap().as_i64().unwrap(), 8, "flat aggregate");
    let workers = stats.get("workers").unwrap().as_arr().unwrap();
    assert_eq!(workers.len(), 2);
    let per_worker: i64 =
        workers.iter().map(|w| w.get("admitted").unwrap().as_i64().unwrap()).sum();
    assert_eq!(per_worker, 8, "per-worker snapshots sum to the aggregate");
    sharded.stop();
}

#[test]
fn sharded_server_exposes_per_worker_metric_series() {
    let srv = start_server_workers(2, 2, 8);
    let (code, _) = http_post_json(
        srv.addr,
        "/v1/generate",
        r#"{"prompt": "label probe", "tokens": 3, "temperature": 0}"#,
    )
    .unwrap();
    assert_eq!(code, 200);
    let (code, text) = http_get_text(srv.addr, "/metrics").unwrap();
    assert_eq!(code, 200);
    // Both workers register their label set at spawn, so the worker="1"
    // series exists even if placement never reached worker 1 here.
    for series in [
        "sct_serve_requests_total{worker=\"0\"}",
        "sct_serve_requests_total{worker=\"1\"}",
        "sct_serve_tokens_out_total{worker=\"0\"}",
        "sct_serve_tokens_out_total{worker=\"1\"}",
        "sct_serve_queue_depth{worker=\"0\"}",
        "sct_serve_queue_depth{worker=\"1\"}",
    ] {
        assert!(text.contains(series), "missing per-worker series {series}");
    }
    srv.stop();
}

#[test]
fn every_error_path_returns_the_envelope() {
    let srv = start_server(1, 2);
    // 400: malformed JSON body
    let (code, body) = http_post_json(srv.addr, "/v1/generate", "{nope").unwrap();
    assert_eq!(code, 400);
    assert_envelope(&body, "bad_request");
    // 400: shape-valid JSON missing the prompt
    let (code, body) = http_post_json(srv.addr, "/v1/generate", r#"{"seed": 1}"#).unwrap();
    assert_eq!(code, 400);
    assert_envelope(&body, "bad_request");
    // 404: unknown route
    let (code, body) = http_get_json(srv.addr, "/v2/unknown").unwrap();
    assert_eq!(code, 404);
    assert_envelope(&body, "not_found");
    // 405: unknown method
    let (code, body) = sct::serve::http_roundtrip(
        srv.addr,
        "PUT /v1/generate HTTP/1.1\r\nHost: t\r\nConnection: close\r\nContent-Length: 0\r\n\r\n",
    )
    .unwrap();
    assert_eq!(code, 405);
    assert_envelope(&body, "method_not_allowed");
    // 413: declared body beyond the 1 MiB cap
    let (code, body) = sct::serve::http_roundtrip(
        srv.addr,
        &format!(
            "POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n",
            (1 << 20) + 1
        ),
    )
    .unwrap();
    assert_eq!(code, 413);
    assert_envelope(&body, "payload_too_large");
    srv.stop();
}

#[test]
fn error_responses_carry_json_content_type() {
    // The envelope is only machine-readable if the headers say it is JSON:
    // read an error response raw off the socket and check its head.
    let srv = start_server(1, 2);
    let mut conn = TcpStream::connect(srv.addr).unwrap();
    conn.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    conn.write_all(b"GET /no/such/route HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n")
        .unwrap();
    let mut text = String::new();
    BufReader::new(conn).read_to_string(&mut text).unwrap();
    let (head, payload) = text.split_once("\r\n\r\n").expect("head/body split");
    assert!(head.starts_with("HTTP/1.1 404 Not Found"), "head: {head:?}");
    assert!(
        head.to_ascii_lowercase().contains("content-type: application/json"),
        "error responses must declare application/json, head: {head:?}"
    );
    let body = Json::parse(payload).expect("error body must parse as JSON");
    assert_envelope(&body, "not_found");
    srv.stop();
}
