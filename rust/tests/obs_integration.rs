//! Cross-layer observability integration: the Prometheus exposition served
//! over HTTP parses and its histograms are monotone, concurrent counter
//! increments from pool workers are never lost, one process surfaces
//! serve + pool + train + rank series on the shared registry, and every
//! HTTP request produces one complete span record.
//!
//! The registry, the trace sink, and the profiler are process-global and
//! tests run concurrently in one binary, so every test serializes on
//! [`obs_lock`], every assertion is delta- or presence-based (never an
//! exact global count), and span lookups filter by this test's own request
//! ids.

use std::collections::BTreeSet;
use std::sync::Mutex;

use sct::data::Tokenizer;
use sct::obs::{self, prof, trace};
use sct::serve::{
    http_get_text, http_post_json, Engine, EngineConfig, ServeConfig, Server, SpectralModel,
};
use sct::train::{NativeTrainConfig, NativeTrainer};
use sct::util::pool;

/// Serialize tests that touch the process-global profiler / trace / metric
/// state (all of them, for simplicity — the binary is small).
fn obs_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn start_server() -> Server {
    let model = SpectralModel::init(EngineConfig::default(), 7);
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        slots: 2,
        queue_depth: 8,
        ..ServeConfig::default()
    };
    Server::start(&cfg, Engine::new(model), Tokenizer::byte_level()).unwrap()
}

/// Strip label set and histogram sub-series suffixes down to the logical
/// metric name (`sct_serve_ttft_ms_bucket{le="1"}` -> `sct_serve_ttft_ms`).
fn base_name(series: &str) -> &str {
    let name = series.split('{').next().unwrap();
    for suffix in ["_bucket", "_sum", "_count"] {
        if let Some(stripped) = name.strip_suffix(suffix) {
            return stripped;
        }
    }
    name
}

#[test]
fn metrics_exposition_parses_and_histogram_buckets_are_monotone() {
    let _g = obs_lock();
    let srv = start_server();
    let req = r#"{"prompt": "exposition probe", "tokens": 3, "temperature": 0}"#;
    let (code, _) = http_post_json(srv.addr, "/v1/generate", req).unwrap();
    assert_eq!(code, 200);
    let (code, text) = http_get_text(srv.addr, "/metrics").unwrap();
    assert_eq!(code, 200);
    srv.stop();

    assert!(!text.is_empty());
    // Every line is `# HELP ...`, `# TYPE ...`, or `series value`.
    let mut samples = 0usize;
    for line in text.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# ") {
            assert!(
                rest.starts_with("HELP ") || rest.starts_with("TYPE "),
                "unexpected comment line: {line}"
            );
            continue;
        }
        let (series, value) = line.rsplit_once(' ').expect("sample lines are `series value`");
        let name = series.split('{').next().unwrap();
        assert!(
            name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':'),
            "bad metric name in: {line}"
        );
        assert!(value.parse::<f64>().is_ok(), "unparseable value in: {line}");
        samples += 1;
    }
    assert!(samples > 0, "exposition must contain sample lines");

    // Bucket lines of one histogram series are emitted consecutively and
    // must be cumulative: group by everything before the le label.
    let mut prev_key: Option<String> = None;
    let mut last = 0u64;
    for line in text.lines() {
        let Some(pos) = line.find("le=\"") else {
            prev_key = None;
            continue;
        };
        let key = line[..pos].to_string();
        let val: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
        if prev_key.as_deref() == Some(key.as_str()) {
            assert!(val >= last, "non-monotone bucket counts at: {line}");
        }
        prev_key = Some(key);
        last = val;
    }
}

#[test]
fn concurrent_pool_increments_are_not_lost() {
    let _g = obs_lock();
    let c = obs::registry().counter("sct_test_obs_fanout_total", "test");
    let before = c.get();
    pool::par_tasks(1000, |_| c.inc());
    assert_eq!(c.get(), before + 1000, "relaxed fetch_add must not drop increments");
}

#[test]
fn one_process_surfaces_series_from_every_layer() {
    let _g = obs_lock();
    // train: one step of a tiny native trainer.
    let model_cfg = EngineConfig {
        vocab: 64,
        d_model: 16,
        n_layers: 1,
        n_heads: 2,
        d_ffn: 24,
        rank: 3,
        max_seq: 16,
        tied: true,
    };
    let tcfg =
        NativeTrainConfig { model: model_cfg, batch: 2, seq_len: 12, ..NativeTrainConfig::default() };
    let mut trainer = NativeTrainer::new(tcfg, 0);
    let tokens: Vec<i32> = (0..2 * 13).map(|i| (i % 64) as i32).collect();
    trainer.train_step(&tokens, 1e-3, 3e-3);

    // rank: publish an energy snapshot, the ortho gauge, and one event.
    let stats = sct::rank::model_energy(&trainer.model, 0.25);
    sct::rank::publish_energy(&stats);
    sct::rank::publish_ortho_error(trainer.ortho_error());
    sct::rank::RankEvent { step: 1, layer: 0, from: 3, to: 4, tail_share: 0.3, policy: "test" }
        .publish();

    // pool: force one real fan-out so the shard series exist even when the
    // test host resolves to a single core.
    let threads_before = pool::threads();
    pool::set_threads(2);
    pool::par_tasks(4, |_| {});
    pool::set_threads(threads_before);

    // serve: one request through the HTTP front-end.
    let srv = start_server();
    let req = r#"{"prompt": "layer sweep probe", "tokens": 3, "temperature": 0}"#;
    let (code, _) = http_post_json(srv.addr, "/v1/generate", req).unwrap();
    assert_eq!(code, 200);
    let (code, text) = http_get_text(srv.addr, "/metrics").unwrap();
    assert_eq!(code, 200);
    srv.stop();

    let mut names: BTreeSet<&str> = BTreeSet::new();
    for line in text.lines() {
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        names.insert(base_name(line.rsplit_once(' ').unwrap().0));
    }
    assert!(names.len() >= 20, "only {} distinct series: {names:?}", names.len());
    for prefix in ["sct_serve_", "sct_http_", "sct_pool_", "sct_train_", "sct_rank_"] {
        assert!(
            names.iter().any(|n| n.starts_with(prefix)),
            "no {prefix} series in: {names:?}"
        );
    }
}

#[test]
fn each_http_request_emits_a_linked_span_tree() {
    let _g = obs_lock();
    let buf = trace::install_memory();
    let srv = start_server();
    let req = r#"{"prompt": "span probe", "tokens": 5, "temperature": 0}"#;
    let (code, body) = http_post_json(srv.addr, "/v1/generate", req).unwrap();
    assert_eq!(code, 200);
    let id = body.get("request_id").unwrap().as_i64().unwrap();
    srv.stop();
    let spans = buf.lock().unwrap().clone();
    trace::uninstall();

    // Other tests in this binary may have traced concurrently: filter by
    // our own request id. One request now yields a span tree — a gateway
    // root, a per-sequence request summary, and queue/prefill/decode
    // children — all linked by parent ids.
    let ours: Vec<_> = spans
        .iter()
        .filter(|s| s.get("request_id").and_then(|v| v.as_i64().ok()) == Some(id))
        .collect();
    let kind_of = |s: &&sct::util::json::Json| {
        s.get("kind").and_then(|v| v.as_str().ok()).unwrap_or_default().to_string()
    };

    // Root: the gateway placement span reuses the request id as its span id.
    let gateway = ours
        .iter()
        .find(|s| kind_of(s) == "gateway")
        .unwrap_or_else(|| panic!("no gateway span for request {id}: {ours:?}"));
    assert_eq!(gateway.get("span_id").unwrap().as_i64().unwrap(), id);
    assert!(gateway.get("worker").is_some(), "gateway span missing worker: {gateway:?}");

    // One request-summary span per request, parented to the gateway root.
    let summaries: Vec<_> = ours.iter().filter(|s| kind_of(s) == "request").collect();
    assert_eq!(summaries.len(), 1, "one request-summary span, got {ours:?}");
    let span = summaries[0];
    assert_eq!(span.get("parent_id").unwrap().as_i64().unwrap(), id);
    let seq_span = span.get("span_id").unwrap().as_i64().unwrap();
    assert!(seq_span > 0 && seq_span != id, "summary span needs its own id: {span:?}");
    for key in [
        "prompt_tokens",
        "queue_ms",
        "prefill_chunks",
        "prefill_tokens",
        "decode_steps",
        "tokens_out",
        "decode_ms",
        "finish_reason",
        "ttft_ms",
    ] {
        assert!(span.get(key).is_some(), "span missing {key}: {span:?}");
    }
    assert_eq!(span.get("tokens_out").unwrap().as_i64().unwrap(), 5);
    assert_eq!(span.get("decode_steps").unwrap().as_i64().unwrap(), 5);
    assert!(span.get("prefill_chunks").unwrap().as_i64().unwrap() >= 1);
    assert_eq!(span.get("finish_reason").unwrap().as_str().unwrap(), "length");

    // Children: queue wait, at least one prefill chunk, and the decode span
    // all hang off the per-sequence summary span.
    for kind in ["queue_wait", "prefill_chunk", "decode"] {
        let children: Vec<_> = ours.iter().filter(|s| kind_of(s) == kind).collect();
        assert!(!children.is_empty(), "no {kind} span for request {id}: {ours:?}");
        for child in &children {
            assert_eq!(
                child.get("parent_id").unwrap().as_i64().unwrap(),
                seq_span,
                "{kind} span not parented to the request summary: {child:?}"
            );
        }
    }
}

#[test]
fn train_profile_tree_matches_trainer_timing() {
    let _g = obs_lock();
    let tcfg = NativeTrainConfig {
        model: EngineConfig::default(),
        batch: 2,
        seq_len: 16,
        ..NativeTrainConfig::default()
    };
    let mut trainer = NativeTrainer::new(tcfg, 3);
    let vocab = trainer.model.cfg.vocab as i32;
    let tokens: Vec<i32> = (0..2 * 17).map(|i| i % vocab).collect();

    prof::reset();
    prof::enable();
    let steps = 5u64;
    let mut phase_sum = 0f64;
    for _ in 0..steps {
        let (_, phases) = trainer.train_step(&tokens, 1e-3, 3e-3);
        phase_sum += phases.iter().sum::<f64>();
    }
    prof::disable();
    let report = prof::snapshot();
    prof::reset();

    let root = report.root("train_step").expect("train_step root in profile tree");
    assert_eq!(root.calls, steps);
    for phase in ["forward", "backward", "optimizer", "retract"] {
        assert!(
            root.children.iter().any(|c| c.name == phase),
            "phase {phase} missing under train_step: {report:?}"
        );
    }

    // Acceptance: the profiler's root wall time agrees with the trainer's
    // own per-phase Instant timing to within 5%.
    let root_secs = root.wall_ns as f64 / 1e9;
    let rel = (root_secs - phase_sum).abs() / phase_sum.max(1e-9);
    assert!(
        rel < 0.05,
        "profiler root {root_secs:.6}s vs trainer phase sum {phase_sum:.6}s ({:.2}% apart)",
        rel * 100.0
    );

    // At least four distinct kernels must carry a work model: nonzero FLOPs
    // and a finite achieved GFLOP/s.
    let kernels = report.kernel_stats();
    let with_work: Vec<_> =
        kernels.iter().filter(|k| k.flops > 0.0 && k.gflops() > 0.0).collect();
    assert!(
        with_work.len() >= 4,
        "expected >=4 kernels with FLOP models, got: {:?}",
        kernels.iter().map(|k| (k.name, k.flops)).collect::<Vec<_>>()
    );
    for name in ["matmul", "attention_fwd", "attention_bwd", "adamw", "qr_retract"] {
        assert!(
            kernels.iter().any(|k| k.name == name && k.flops > 0.0),
            "kernel {name} missing from profile: {kernels:?}"
        );
    }
}
