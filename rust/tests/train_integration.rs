//! End-to-end test of the native training engine: train a tiny spectral
//! model on the bundled synthetic corpus with NO PJRT anywhere, watch the
//! loss fall, checkpoint to `.sct` in the `params/layers/...` layout, load
//! the checkpoint straight into the serving engine, and decode
//! deterministically — the full train → checkpoint → serve loop the
//! subsystem exists for.

use sct::coordinator::schedule::{LrPlan, Schedule};
use sct::data::build_dataset;
use sct::serve::{
    http_post_json, Engine, EngineConfig, SampleOpts, ServeConfig, Server, SpectralModel,
};
use sct::train::{NativeTrainConfig, NativeTrainer};

fn train_cfg() -> NativeTrainConfig {
    NativeTrainConfig {
        model: EngineConfig {
            vocab: 256, // byte-level tokenizer
            d_model: 32,
            n_layers: 2,
            n_heads: 4,
            d_ffn: 48,
            rank: 4,
            max_seq: 64,
            tied: true,
        },
        batch: 4,
        seq_len: 24,
        grad_clip: 1.0,
        retract_every: 1,
        weight_decay: 0.0,
    }
}

#[test]
fn native_train_checkpoint_serve_loop() {
    let cfg = train_cfg();
    let steps = 60usize;
    // warmup + cosine — the coordinator/schedule.rs plan the native loop runs
    let plan = LrPlan {
        dense: Schedule::WarmupCosine { peak: 3e-3, floor: 3e-4, warmup: 5, total: steps },
        spectral: Schedule::WarmupCosine { peak: 3e-3, floor: 3e-4, warmup: 5, total: steps },
    };

    // -- train on the bundled synthetic corpus -----------------------------
    let (_tok, mut dataset) =
        build_dataset(cfg.model.vocab, cfg.batch, cfg.seq_len + 1, 200_000, 0);
    let mut trainer = NativeTrainer::new(cfg, 0);
    let mut losses = Vec::with_capacity(steps);
    for step in 0..steps {
        let (ld, ls) = plan.at(step);
        let (loss, phases) = trainer.train_step(&dataset.next_batch(), ld, ls);
        assert!(loss.is_finite(), "step {step}: loss went non-finite");
        assert!(phases.iter().all(|&p| p >= 0.0));
        losses.push(loss);
    }

    // loss strictly decreases over the run (head-vs-tail means, robust to
    // per-step noise)
    let head: f32 = losses[..10].iter().sum::<f32>() / 10.0;
    let tail: f32 = losses[steps - 10..].iter().sum::<f32>() / 10.0;
    assert!(
        tail < head * 0.9,
        "loss must fall over {steps} native steps: head mean {head:.3}, tail mean {tail:.3}"
    );

    // factors stayed on the manifold (paper budget)
    let ortho = trainer.ortho_error();
    assert!(ortho <= 2e-6, "ortho error {ortho} after training");

    // -- checkpoint --------------------------------------------------------
    let dir = std::env::temp_dir().join(format!("sct_e2e_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("trained.sct");
    trainer.save(&ckpt).unwrap();

    // -- serve the trained checkpoint --------------------------------------
    // (SpectralModel::load ignores the opt/* tensors the trainer wrote)
    let model = SpectralModel::load(&ckpt).unwrap();
    assert_eq!(model.cfg, trainer.model.cfg);
    let engine = Engine::new(model);

    let opts = SampleOpts { temperature: 0.0, top_k: 0, seed: 0 };
    let prompt: Vec<i32> = "### Instruction".bytes().map(|b| b as i32).collect();
    let a = engine.generate_reencode(&prompt, 16, &opts);
    let b = engine.generate_reencode(&prompt, 16, &opts);
    assert_eq!(a, b, "temperature-0 decode must be deterministic");
    assert_eq!(a.len(), 16);

    // the served engine computes exactly what the trainer's model computes
    let direct = Engine::new(SpectralModel::from_tensors(&trainer.checkpoint_tensors()).unwrap());
    assert_eq!(a, direct.generate_reencode(&prompt, 16, &opts));

    // KV-cached serving path agrees with the baseline on the trained model
    let mut kv = engine.new_kv(1);
    let slot = kv.alloc().unwrap();
    assert_eq!(a, engine.generate_kv(&prompt, 16, &opts, &mut kv, slot));

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn trained_checkpoint_serves_over_http() {
    // Short training run, then the full server stack on the checkpoint.
    let cfg = train_cfg();
    let (_tok, mut dataset) =
        build_dataset(cfg.model.vocab, cfg.batch, cfg.seq_len + 1, 120_000, 1);
    let mut trainer = NativeTrainer::new(cfg, 1);
    for _ in 0..10 {
        trainer.train_step(&dataset.next_batch(), 1e-3, 1e-3);
    }
    let dir = std::env::temp_dir().join(format!("sct_e2e_http_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("served.sct");
    trainer.save(&ckpt).unwrap();

    let model = SpectralModel::load(&ckpt).unwrap();
    let serve_cfg = ServeConfig { addr: "127.0.0.1:0".into(), ..ServeConfig::default() };
    let server = Server::start(
        &serve_cfg,
        Engine::new(model),
        sct::data::Tokenizer::byte_level(),
    )
    .unwrap();
    let req = r#"{"prompt": "spectral compact", "tokens": 8, "temperature": 0}"#;
    let (code, a) = http_post_json(server.addr, "/v1/generate", req).unwrap();
    assert_eq!(code, 200, "body: {a:?}");
    assert_eq!(a.get("tokens").unwrap().as_arr().unwrap().len(), 8);
    let (_, b) = http_post_json(server.addr, "/v1/generate", req).unwrap();
    assert_eq!(
        a.get("tokens").unwrap(),
        b.get("tokens").unwrap(),
        "trained checkpoint must serve deterministically at T=0"
    );
    server.stop();
    std::fs::remove_dir_all(&dir).ok();
}
