//! Parallel-kernel scaling benchmark: matmul, attention, full native
//! training step, and serve decode throughput vs worker-pool thread count,
//! at spectral ranks 32 and 128 — the wall-clock evidence that the
//! compact-factor math saturates the cores (`util::pool` layer).
//!
//! Each section re-runs the identical workload at 1/2/4 pool threads
//! (`pool::set_threads`; results are bit-identical across settings, only
//! the wall time moves) and reports the speedup over the single-thread
//! baseline.
//!
//! Run: `cargo bench --bench kernel_scaling`
//! Flags: `--smoke` (small shapes, CI mode; also via `SCT_BENCH_SMOKE`),
//! `--json PATH` (write `BENCH_kernels.json` for the CI base-branch diff),
//! and `--profile-json PATH` (run the `obs::prof` roofline pass — native
//! train steps at ranks 32 and 128, profiler on — and write per-kernel
//! achieved GFLOP/s + arithmetic intensity there, plus collapsed flamegraph
//! stacks at the sibling `.folded` path; the profile pass runs at both
//! ranks even in smoke mode, CI gates on the mandatory kernels being
//! present).
//!
//! The `matmul_gflops` section measures single-thread blocked-kernel
//! GFLOP/s at ranks 32 AND 128 regardless of smoke mode: its rank-128 rows
//! feed the CI kernel-regression gate (`scripts/bench_diff.py --gate`,
//! fail if GFLOP/s drops >15% vs the base branch). Both JSON docs record
//! the detected SIMD feature set (`"simd"`) next to the numbers so a
//! regression on a differently-featured runner is attributable.

use std::time::Instant;

use sct::json_obj;
use sct::obs::prof;
use sct::serve::{Engine, EngineConfig, SampleOpts, SpectralModel};
use sct::spectral::{Matrix, SpectralLinear};
use sct::train::blocks::causal_attention_fwd_batched;
use sct::train::{NativeTrainConfig, NativeTrainer};
use sct::util::json::Json;
use sct::util::pool;
use sct::util::rng::Rng;

#[derive(Clone, Copy)]
struct Workload {
    ranks: &'static [usize],
    threads: &'static [usize],
    d_model: usize,
    d_ffn: usize,
    n_heads: usize,
    /// batch rows through the matmul section
    mm_rows: usize,
    /// attention section geometry
    attn_bsz: usize,
    attn_t: usize,
    /// native train-step section
    batch: usize,
    seq_len: usize,
    steps: usize,
    /// serve decode section
    decode_tokens: usize,
}

const FULL: Workload = Workload {
    ranks: &[32, 128],
    threads: &[1, 2, 4],
    d_model: 256,
    d_ffn: 512,
    n_heads: 8,
    mm_rows: 512,
    attn_bsz: 4,
    attn_t: 128,
    batch: 4,
    seq_len: 32,
    steps: 4,
    decode_tokens: 48,
};

const SMOKE: Workload = Workload {
    ranks: &[32],
    threads: &[1, 2],
    d_model: 128,
    d_ffn: 256,
    n_heads: 4,
    mm_rows: 256,
    attn_bsz: 2,
    attn_t: 64,
    batch: 2,
    seq_len: 24,
    steps: 2,
    decode_tokens: 24,
};

/// Roofline pass: profiler on, a few full native train steps at ranks 32
/// and 128 (always both, even in smoke — CI gates on these rows), then per-
/// kernel achieved GFLOP/s / arithmetic intensity against the calibrated
/// machine peak, written as `BENCH_profile.json` plus collapsed flamegraph
/// stacks at the sibling `.folded` path.
fn run_profile_pass(w: &Workload, path: &str) {
    let peak = prof::machine_peak_gflops();
    println!("\nprofile pass (machine peak {peak:.2} GFLOP/s):");
    let mut rank_docs: Vec<Json> = Vec::new();
    let mut folded = String::new();
    for &rank in &[32usize, 128] {
        let cfg = NativeTrainConfig {
            model: EngineConfig {
                vocab: 256,
                d_model: w.d_model.max(rank),
                n_layers: 2,
                n_heads: w.n_heads,
                d_ffn: w.d_ffn.max(rank),
                rank,
                max_seq: w.seq_len.max(2),
                tied: true,
            },
            batch: w.batch,
            seq_len: w.seq_len,
            grad_clip: 1.0,
            retract_every: 1,
            weight_decay: 0.0,
        };
        let window = w.batch * (w.seq_len + 1);
        let mut trainer = NativeTrainer::new(cfg, 0);
        let mut rng = Rng::new(7);
        prof::reset();
        prof::enable();
        {
            // One static root per rank so the concatenated .folded keeps the
            // two passes' stacks distinguishable.
            let _root = prof::scope(if rank == 32 { "profile_r32" } else { "profile_r128" });
            for _ in 0..w.steps.max(2) {
                let b: Vec<i32> = (0..window).map(|_| rng.below(256) as i32).collect();
                trainer.train_step(&b, 5e-4, 5e-4);
            }
        }
        prof::disable();
        let report = prof::snapshot();
        folded.push_str(&report.render_folded());
        let kernels: Vec<Json> = report
            .kernel_stats()
            .iter()
            .map(|k| {
                println!(
                    "  r{rank} {:<14} {:>7.2} GFLOP/s  {:>6.3} FLOP/byte  {:>5.1}% peak",
                    k.name,
                    k.gflops(),
                    k.intensity(),
                    100.0 * k.gflops() / peak,
                );
                json_obj![
                    ("kernel", k.name),
                    ("calls", k.calls as i64),
                    ("self_ms", k.self_ns as f64 / 1e6),
                    ("flops", k.flops),
                    ("bytes", k.bytes),
                    ("gflops", k.gflops()),
                    ("intensity", k.intensity()),
                    ("peak_fraction", k.gflops() / peak),
                ]
            })
            .collect();
        rank_docs.push(json_obj![("rank", rank), ("kernels", kernels)]);
    }
    let doc = json_obj![
        ("bench", "kernel_scaling_profile"),
        ("machine_peak_gflops", peak),
        ("simd", sct::spectral::microkernel::detected_features()),
        ("ranks", rank_docs),
    ];
    std::fs::write(path, doc.to_string()).expect("writing profile JSON");
    let folded_path = std::path::Path::new(path).with_extension("folded");
    std::fs::write(&folded_path, folded).expect("writing profile folded stacks");
    println!("wrote {path} and {}", folded_path.display());
}

/// Median-free simple timer: warmup once, then average `iters` runs.
fn time_ms<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() * 1e3 / iters as f64
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let smoke = argv.iter().any(|a| a == "--smoke") || std::env::var("SCT_BENCH_SMOKE").is_ok();
    let json_path =
        argv.iter().position(|a| a == "--json").and_then(|i| argv.get(i + 1).cloned());
    let profile_path = argv
        .iter()
        .position(|a| a == "--profile-json")
        .and_then(|i| argv.get(i + 1).cloned());
    let w = if smoke { SMOKE } else { FULL };

    println!(
        "kernel scaling{}: d_model={}, d_ffn={}, heads={}, threads {:?}",
        if smoke { " [smoke]" } else { "" },
        w.d_model,
        w.d_ffn,
        w.n_heads,
        w.threads,
    );
    println!("| section | rank | threads | ms | speedup | tok/s |");
    println!("|---|---|---|---|---|---|");

    let mut rows: Vec<Json> = Vec::new();
    let mut emit = |section: &str, rank: usize, threads: usize, ms: f64, base_ms: f64, tps: f64| {
        let speedup = if ms > 0.0 { base_ms / ms } else { 0.0 };
        println!(
            "| {section} | {rank} | {threads} | {ms:.2} | {speedup:.2}x | {} |",
            if tps > 0.0 { format!("{tps:.0}") } else { "-".to_string() },
        );
        rows.push(json_obj![
            ("section", section),
            // "mode" keys the row in scripts/bench_diff.py's flattened diff
            ("mode", format!("{section}@t{threads}")),
            ("rank", rank),
            ("threads", threads),
            ("ms", ms),
            ("speedup_vs_1", speedup),
            ("tok_per_s", tps),
        ]);
    };

    // -- spectral projection matmuls (x U diag(s) V^T) -----------------------
    for &rank in w.ranks {
        let mut rng = Rng::new(1);
        let layer = SpectralLinear::init(&mut rng, w.d_model, w.d_ffn, rank);
        let x = Matrix::randn(&mut rng, w.mm_rows, w.d_model, 1.0);
        let mut base = 0.0f64;
        for &t in w.threads {
            pool::set_threads(t);
            let ms = time_ms(2, 8, || {
                let (y, _) = layer.forward(&x);
                std::hint::black_box(&y);
            });
            if t == 1 {
                base = ms;
            }
            emit("spectral_matmul", rank, t, ms, base, 0.0);
        }
    }

    // -- head-parallel causal attention forward ------------------------------
    {
        let n = w.attn_bsz * w.attn_t * w.d_model;
        let mut rng = Rng::new(2);
        let q: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let k: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let v: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let mut out = vec![0.0f32; n];
        let mut probs = vec![0.0f32; w.attn_bsz * w.n_heads * w.attn_t * w.attn_t];
        let mut base = 0.0f64;
        for &t in w.threads {
            pool::set_threads(t);
            let ms = time_ms(1, 6, || {
                out.fill(0.0);
                causal_attention_fwd_batched(
                    &q,
                    &k,
                    &v,
                    w.attn_bsz,
                    w.attn_t,
                    w.n_heads,
                    w.d_model,
                    &mut out,
                    &mut probs,
                );
            });
            if t == 1 {
                base = ms;
            }
            emit("attention_fwd", 0, t, ms, base, 0.0);
        }
    }

    // -- full native training step (fwd+bwd+opt+retract) ---------------------
    for &rank in w.ranks {
        let cfg = NativeTrainConfig {
            model: EngineConfig {
                vocab: 256,
                d_model: w.d_model,
                n_layers: 2,
                n_heads: w.n_heads,
                d_ffn: w.d_ffn,
                rank,
                max_seq: w.seq_len.max(2),
                tied: true,
            },
            batch: w.batch,
            seq_len: w.seq_len,
            grad_clip: 1.0,
            retract_every: 1,
            weight_decay: 0.0,
        };
        let window = w.batch * (w.seq_len + 1);
        let mut base = 0.0f64;
        for &t in w.threads {
            pool::set_threads(t);
            let mut trainer = NativeTrainer::new(cfg, 0);
            let mut rng = Rng::new(42);
            let tokens = w.batch * w.seq_len;
            let ms = time_ms(1, w.steps, || {
                let b: Vec<i32> = (0..window).map(|_| rng.below(256) as i32).collect();
                trainer.train_step(&b, 5e-4, 5e-4);
            });
            if t == 1 {
                base = ms;
            }
            let tps = tokens as f64 / (ms / 1e3);
            emit("train_step", rank, t, ms, base, tps);
        }
    }

    // -- serve decode (KV incremental, fused prefill + decode loop) ----------
    {
        let cfg = EngineConfig {
            vocab: 256,
            d_model: w.d_model,
            n_layers: 2,
            n_heads: w.n_heads,
            d_ffn: w.d_ffn,
            rank: w.ranks[0],
            max_seq: w.decode_tokens + 16,
            tied: true,
        };
        let engine = Engine::new(SpectralModel::init(cfg, 0));
        let opts = SampleOpts { temperature: 0.0, top_k: 0, seed: 0 };
        let prompt: Vec<i32> = (0..8).map(|i| (i * 31 + 5) % 256).collect();
        let mut base = 0.0f64;
        for &t in w.threads {
            pool::set_threads(t);
            let ms = time_ms(1, 3, || {
                let mut kv = engine.new_kv(1);
                let slot = kv.alloc().unwrap();
                let out = engine.generate_kv(&prompt, w.decode_tokens, &opts, &mut kv, slot);
                std::hint::black_box(&out);
            });
            if t == 1 {
                base = ms;
            }
            let tps = w.decode_tokens as f64 / (ms / 1e3);
            emit("serve_decode", cfg.rank, t, ms, base, tps);
        }
    }

    // -- single-thread blocked-kernel GFLOP/s (CI regression gate) -----------
    // Runs ranks 32 AND 128 even in smoke mode: scripts/bench_diff.py gates
    // on the rank-128 rows (CI fails if matmul GFLOP/s drops >15% vs the
    // base branch), so they must exist in every BENCH_kernels.json.
    pool::set_threads(1);
    for &rank in &[32usize, 128] {
        let mut rng = Rng::new(3);
        let x = Matrix::randn(&mut rng, w.mm_rows, w.d_model, 1.0);
        let u = Matrix::randn(&mut rng, w.d_model, rank, 1.0);
        let hs = Matrix::randn(&mut rng, w.mm_rows, rank, 1.0);
        let v = Matrix::randn(&mut rng, w.d_ffn, rank, 1.0);
        let mm_flops = 2.0 * w.mm_rows as f64 * w.d_model as f64 * rank as f64;
        let mt_flops = 2.0 * w.mm_rows as f64 * rank as f64 * w.d_ffn as f64;
        let mm_ms = time_ms(2, 10, || {
            std::hint::black_box(&x.matmul(&u));
        });
        let mt_ms = time_ms(2, 10, || {
            std::hint::black_box(&hs.matmul_t(&v));
        });
        let g_mm = mm_flops / (mm_ms * 1e6);
        let g_mt = mt_flops / (mt_ms * 1e6);
        println!(
            "| matmul_gflops | {rank} | 1 | {mm_ms:.3} | {g_mm:.2} GF/s mm / {g_mt:.2} GF/s mmT | - |"
        );
        rows.push(json_obj![
            ("section", "matmul_gflops"),
            ("mode", format!("matmul_gflops@r{rank}")),
            ("rank", rank),
            ("threads", 1usize),
            ("ms", mm_ms),
            ("matmul_t_ms", mt_ms),
            ("gflops_matmul", g_mm),
            ("gflops_matmul_t", g_mt),
        ]);
    }

    let simd = sct::spectral::microkernel::detected_features();
    println!("simd: {simd}");

    if let Some(path) = profile_path {
        run_profile_pass(&w, &path);
    }

    if let Some(path) = json_path {
        let doc = json_obj![
            ("bench", "kernel_scaling"),
            ("smoke", smoke),
            ("simd", simd),
            ("d_model", w.d_model),
            ("d_ffn", w.d_ffn),
            ("n_heads", w.n_heads),
            ("rows", rows),
        ];
        std::fs::write(&path, doc.to_string()).expect("writing bench JSON");
        println!("\nwrote {path}");
    }
}
