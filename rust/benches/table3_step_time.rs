//! Bench regenerating paper Table 3's step-time and memory columns: real
//! train-step dispatches through the PJRT runtime for the dense baseline and
//! every SCT rank.
//!
//! The paper's throughput claim — SCT steps get faster as rank drops (2.1x
//! at the lowest rank) and every rank beats dense — is asserted at the end.
//! Loss/PPL columns come from `examples/rank_sweep.rs` (they need thousands
//! of steps, not a bench harness).
//!
//! Requires `make artifacts`. Run: `cargo bench --bench table3_step_time`

use sct::runtime::Session;
use sct::util::bench::{table_header, table_row, Bench};

fn main() -> anyhow::Result<()> {
    let root = std::path::Path::new("artifacts");
    if !root.join("manifest.json").exists() {
        eprintln!("artifacts/ missing — run `make artifacts` first; skipping bench");
        return Ok(());
    }

    let presets =
        ["sweep_dense", "sweep_r64", "sweep_r32", "sweep_r16", "sweep_r8"];
    let mut rows = Vec::new();
    let mut bench = Bench::heavy();

    for preset in presets {
        let mut s = Session::open(root, preset)?;
        s.init(0)?;
        s.warmup(&["train_step"])?;
        let spec = s.preset.tokens_spec()?.clone();
        let n = spec.elements();
        let vocab = s.preset.model.vocab;
        let tokens: Vec<i32> = (0..n).map(|i| (i % vocab) as i32).collect();

        let stats = bench.run(&format!("train_step/{preset}"), || {
            s.train_step(&tokens, 2e-5, 5e-4).expect("step");
        });
        rows.push((
            preset.to_string(),
            s.preset.model.param_count as f64 / 1e6,
            s.preset.model.rank,
            s.preset.state_bytes() as f64 / 1e6,
            stats.median() / 1e6, // ms
        ));
    }

    table_header(
        "Table 3 (memory + step-time columns; loss/PPL from examples/rank_sweep)",
        &["Method", "Params", "State Mem.", "Step Time"],
    );
    let dense_ms = rows[0].4;
    let dense_mb = rows[0].3;
    for (name, params, rank, mb, ms) in &rows {
        table_row(&[
            match rank {
                None => "Dense".to_string(),
                Some(k) => format!("SCT r={k}"),
            },
            format!("{params:.1}M"),
            format!("{mb:.1} MB ({:.0}%)", mb / dense_mb * 100.0),
            format!("{ms:.1} ms ({:.2}x)", dense_ms / ms),
        ]);
        let _ = name;
    }

    // Paper claims, asserted:
    let fastest = rows[1..].iter().map(|r| r.4).fold(f64::INFINITY, f64::min);
    assert!(
        fastest < dense_ms,
        "every SCT rank should beat dense step time (paper: 2.1x at lowest rank)"
    );
    let min_mem = rows[1..].iter().map(|r| r.3).fold(f64::INFINITY, f64::min);
    assert!(min_mem < dense_mb, "SCT state must undercut dense");
    // memory monotone in rank
    let mems: Vec<f64> = rows[1..].iter().map(|r| r.3).collect();
    for w in mems.windows(2) {
        assert!(w[1] <= w[0] + 1e-6, "state memory should fall with rank");
    }
    println!("\npaper's throughput/memory ordering reproduced");
    Ok(())
}
