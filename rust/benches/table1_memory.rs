//! Bench + regeneration of paper Table 1 (per-MLP-layer memory at k=32)
//! and Figure 1 (70B training memory).
//!
//! The "benchmark" aspect times the analytic model itself (it sits on the
//! CLI path) — the substantive output is the table, printed in the paper's
//! format with the paper's expected values asserted.
//!
//! Run: `cargo bench --bench table1_memory`

use sct::memmodel::layer::LayerMemory;
use sct::memmodel::presets::paper_models;
use sct::memmodel::report::{baseline_rows, render_fig1, render_table1};
use sct::memmodel::TrainRegime;
use sct::util::bench::Bench;

fn main() {
    println!("=== Table 1 / Figure 1 regeneration ===\n");
    println!("{}", render_table1(32));
    println!("{}", render_fig1(32));
    println!("baseline accounting (70B MLP stack, GB):");
    for (name, gb) in baseline_rows(32) {
        println!("  {name:<12} {gb:>10.1}");
    }

    // Cross-check every paper row programmatically (hard failure on drift).
    for pm in paper_models() {
        let l = LayerMemory::fp32(pm.shape.d_model, pm.shape.d_ffn);
        let c = l.compression(32);
        assert!(
            (c - pm.table1_compression).abs() / pm.table1_compression < 0.03,
            "{}: compression {c:.1} vs paper {}",
            pm.name,
            pm.table1_compression
        );
    }
    println!("\nall six Table 1 compression factors match the paper (±3%)\n");

    // Timing: full-table generation cost (the CLI hot path).
    let mut b = Bench::new();
    b.run("memmodel/table1_render", || {
        let s = render_table1(32);
        std::hint::black_box(s);
    });
    b.run("memmodel/layer_accounting_6rows", || {
        for pm in paper_models() {
            let l = LayerMemory::fp32(pm.shape.d_model, pm.shape.d_ffn);
            std::hint::black_box(l.dense_bytes(TrainRegime::AdamW));
            std::hint::black_box(l.spectral_bytes(32, TrainRegime::AdamW));
        }
    });
}
