//! Bench regenerating paper Table 2: one full SCT training step at the TRUE
//! 70B factor shapes (8192x28672 @ k=32), phase by phase — forward,
//! backward, AdamW, QR retraction — through the native rust SpectralLinear.
//!
//! This is the experiment the paper ran on a Steam Deck; absolute times
//! differ by host, the *structure* (retraction and optimizer dominate; the
//! whole thing fits in a few GB) is the reproduced claim.
//!
//! Run: `cargo bench --bench table2_70b_step`

use sct::coordinator::validate70b::{measure_70b_phases, render_table2};
use sct::spectral::{LayerTrainer, Matrix, SpectralLinear};
use sct::util::bench::{fmt_ns, Bench};
use sct::util::rng::Rng;

fn main() {
    let k = 32;
    let batch = 4;

    // Per-phase timing at the exact Table 1 row shapes (one (d,f) matrix).
    let mut rng = Rng::new(0);
    let (d, f) = (8192, 28672);
    println!("=== per-phase timing, single 70B MLP projection ({d}x{f} @ k={k}) ===\n");
    let layer = SpectralLinear::init(&mut rng, d, f, k);
    println!(
        "spectral params: {} ({:.1} MB as f32) — dense would be {:.0} MB",
        layer.param_count(),
        layer.param_count() as f64 * 4.0 / 1e6,
        (d * f) as f64 * 4.0 / 1e6
    );
    let mut trainer = LayerTrainer::new(layer, 5e-4);
    let x = Matrix::randn(&mut rng, batch, d, 1.0);
    let t = Matrix::randn(&mut rng, batch, f, 0.5);

    let mut fwd = Vec::new();
    let mut bwd = Vec::new();
    let mut opt = Vec::new();
    let mut retract = Vec::new();
    for _ in 0..5 {
        let (_, phases) = trainer.step(&x, &t);
        fwd.push(phases[0] * 1e9);
        bwd.push(phases[1] * 1e9);
        opt.push(phases[2] * 1e9);
        retract.push(phases[3] * 1e9);
    }
    let mut b = Bench::new();
    b.record("70b_layer/forward", fwd);
    b.record("70b_layer/backward", bwd);
    b.record("70b_layer/adamw", opt);
    b.record("70b_layer/qr_retract", retract);

    // Whole-architecture extrapolation (the actual Table 2).
    println!("\n=== Table 2 (2 layers measured, 80 extrapolated) ===\n");
    let phases = measure_70b_phases(k, batch, 2).expect("phase measurement");
    println!("{}", render_table2(k, &phases));
    assert!(phases.ortho_error < 2e-6);

    // Sanity: retraction must be a major cost (paper: 40-50% of the step).
    println!(
        "retraction fraction: {:.0}% — paper reports 40-50% on Steam Deck\n",
        phases.retract_fraction() * 100.0
    );
    println!(
        "total extrapolated step: {} (paper: 6.28 s on Steam Deck, 3.41 s on M4 Pro)",
        fmt_ns(phases.total_s() * 1e9)
    );
}
