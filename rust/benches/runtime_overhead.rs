//! Runtime-layer benchmarks: PJRT dispatch overhead, the host round-trip
//! tax, and the train_chunk amortization — the L3 numbers behind the §Perf
//! section of EXPERIMENTS.md.
//!
//! Key comparison: `train_step x10` vs `train_chunk(K=10)`. The PJRT shim
//! returns tuple outputs via the host, so per-step dispatch pays 2x state
//! traffic every step; the fused chunk pays it once per K steps.
//!
//! Requires `make artifacts`. Run: `cargo bench --bench runtime_overhead`

use sct::runtime::Session;
use sct::util::bench::Bench;

fn main() -> anyhow::Result<()> {
    let root = std::path::Path::new("artifacts");
    if !root.join("manifest.json").exists() {
        eprintln!("artifacts/ missing — run `make artifacts` first; skipping bench");
        return Ok(());
    }

    let mut bench = Bench::heavy();

    for preset in ["tiny_r8", "sweep_r16"] {
        let mut s = Session::open(root, preset)?;
        s.init(0)?;
        s.warmup(&["train_step", "train_chunk", "eval_step", "forward"])?;
        let spec = s.preset.tokens_spec()?.clone();
        let vocab = s.preset.model.vocab;
        let per = spec.elements();
        let tokens: Vec<i32> = (0..per).map(|i| (i % vocab) as i32).collect();
        let k = s.chunk_len().unwrap_or(10);
        let chunk_tokens: Vec<i32> = (0..per * k).map(|i| (i % vocab) as i32).collect();

        println!(
            "\n=== {preset}: state {:.1} MB, {} tensors ===",
            s.preset.state_bytes() as f64 / 1e6,
            s.preset.n_state
        );

        let step = bench.run(&format!("{preset}/train_step_x1"), || {
            s.train_step(&tokens, 1e-3, 1e-3).expect("step");
        });
        let per_step_ns = step.median();

        let chunk = bench.run(&format!("{preset}/train_chunk_k{k}"), || {
            s.train_chunk(&chunk_tokens, 1e-3, 1e-3).expect("chunk");
        });
        let per_chunk_step_ns = chunk.median() / k as f64;
        println!(
            "  amortized: {:.2} ms/step fused vs {:.2} ms/step loose ({:.2}x)",
            per_chunk_step_ns / 1e6,
            per_step_ns / 1e6,
            per_step_ns / per_chunk_step_ns
        );

        bench.run(&format!("{preset}/eval_step"), || {
            s.eval_step(&tokens).expect("eval");
        });

        // Dispatch-only floor: ortho_check moves params in, one f32 out.
        bench.run(&format!("{preset}/ortho_check_dispatch"), || {
            s.ortho_check().expect("ortho");
        });
    }

    println!("\n(fused chunks are the default driver path; see EXPERIMENTS.md §Perf)");
    Ok(())
}
