//! Data-pipeline benchmarks: corpus generation, BPE training/encoding,
//! batch packing, prefetch overhead. The pipeline must never be the
//! bottleneck (train steps are ~tens of ms; batches must be µs).
//!
//! Run: `cargo bench --bench data_pipeline`

use sct::data::{build_dataset, CorpusGen, Dataset, Prefetcher, Tokenizer};
use sct::util::bench::Bench;

fn main() {
    let mut b = Bench::new();

    b.run("corpus/generate_1MB", || {
        let text = CorpusGen::new(0).generate(1 << 20);
        std::hint::black_box(text.len());
    });

    let text = CorpusGen::new(0).generate(1 << 20);
    b.run("tokenizer/bpe_train_v512_1MB", || {
        let t = Tokenizer::train_bpe(&text[..256 << 10], 512);
        std::hint::black_box(t.vocab_size);
    });

    let tok = Tokenizer::train_bpe(&text[..256 << 10], 512);
    b.run("tokenizer/encode_64KB", || {
        std::hint::black_box(tok.encode(&text[..64 << 10]).len());
    });

    let ids = tok.encode(&text);
    let mut ds = Dataset::new(ids.clone(), 4, 129, 0);
    b.run("dataset/next_batch_4x129", || {
        std::hint::black_box(ds.next_batch());
    });
    b.run("dataset/next_chunk_k10", || {
        std::hint::black_box(ds.next_chunk(10));
    });

    // Prefetcher throughput: consuming from the channel must be far cheaper
    // than generating inline.
    let (_t, ds2) = build_dataset(512, 4, 129, 1 << 20, 0);
    let pf = Prefetcher::spawn(ds2, 10, 4);
    let _ = pf.next(); // warm the queue
    b.run("prefetcher/next_chunk_k10_warm", || {
        std::hint::black_box(pf.next());
    });

    println!("\n(data path must stay < ~1 ms/batch; train steps are 10-1000x that)");
}
