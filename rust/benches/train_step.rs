//! Native training-step benchmark: the paper's Table 2 phase decomposition
//! (forward / backward / optimizer / retraction) measured on the pure-Rust
//! engine at ranks 32 and 128, plus end-to-end step time and training
//! token throughput.
//!
//! The phase timers come straight from `NativeTrainer::train_step` (each
//! step reports its own `[fwd, bwd, opt, retract]` wall times), so the
//! split reflects exactly what the training loop pays — including the
//! per-step QR retraction the paper names as its dominant overhead.
//!
//! Run: `cargo bench --bench train_step`
//! Flags: `--smoke` (tiny shape, few steps — the CI mode; also enabled by
//! the `SCT_BENCH_SMOKE` env var) and `--json PATH` (write the numbers as
//! one JSON document, e.g. `BENCH_train.json`, so CI can compare the perf
//! trajectory against the base branch).

use sct::json_obj;
use sct::serve::EngineConfig;
use sct::train::{NativeTrainConfig, NativeTrainer};
use sct::util::bench::{table_header, table_row};
use sct::util::json::Json;
use sct::util::rng::Rng;

#[derive(Clone, Copy)]
struct Workload {
    ranks: &'static [usize],
    d_model: usize,
    d_ffn: usize,
    n_heads: usize,
    batch: usize,
    seq_len: usize,
    warmup: usize,
    steps: usize,
}

const FULL: Workload = Workload {
    ranks: &[32, 128],
    d_model: 256,
    d_ffn: 512,
    n_heads: 8,
    batch: 4,
    seq_len: 32,
    warmup: 1,
    steps: 8,
};

const SMOKE: Workload = Workload {
    ranks: &[8],
    d_model: 64,
    d_ffn: 128,
    n_heads: 4,
    batch: 2,
    seq_len: 16,
    warmup: 1,
    steps: 3,
};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let smoke = argv.iter().any(|a| a == "--smoke") || std::env::var("SCT_BENCH_SMOKE").is_ok();
    let json_path =
        argv.iter().position(|a| a == "--json").and_then(|i| argv.get(i + 1).cloned());
    let w = if smoke { SMOKE } else { FULL };

    println!(
        "native train step{}: batch {} x seq {}, d_model={}, 2 layers, {} measured steps",
        if smoke { " [smoke]" } else { "" },
        w.batch,
        w.seq_len,
        w.d_model,
        w.steps,
    );

    table_header(
        "Training phase split (native engine)",
        &["rank", "fwd ms", "bwd ms", "opt ms", "retract ms", "step ms", "tok/s", "retract %"],
    );

    let mut rows: Vec<Json> = Vec::new();
    for &rank in w.ranks {
        let cfg = NativeTrainConfig {
            model: EngineConfig {
                vocab: 256,
                d_model: w.d_model,
                n_layers: 2,
                n_heads: w.n_heads,
                d_ffn: w.d_ffn,
                rank,
                max_seq: w.seq_len.max(2),
                tied: true,
            },
            batch: w.batch,
            seq_len: w.seq_len,
            grad_clip: 1.0,
            retract_every: 1,
            weight_decay: 0.0,
        };
        let mut trainer = NativeTrainer::new(cfg, 0);
        let mut rng = Rng::new(42);
        let window = w.batch * (w.seq_len + 1);
        let batch = |rng: &mut Rng| -> Vec<i32> {
            (0..window).map(|_| rng.below(256) as i32).collect()
        };
        for _ in 0..w.warmup {
            trainer.train_step(&batch(&mut rng), 5e-4, 5e-4);
        }
        let mut phases = [0.0f64; 4];
        for _ in 0..w.steps {
            let (_, p) = trainer.train_step(&batch(&mut rng), 5e-4, 5e-4);
            for (acc, v) in phases.iter_mut().zip(p) {
                *acc += v;
            }
        }
        let n = w.steps as f64;
        let [fwd, bwd, opt, retract] = phases.map(|p| p / n * 1e3); // ms/step
        let step_ms = fwd + bwd + opt + retract;
        let tok_per_s = (w.batch * w.seq_len) as f64 / (step_ms / 1e3);
        let retract_pct = 100.0 * retract / step_ms;
        table_row(&[
            format!("{rank}"),
            format!("{fwd:.2}"),
            format!("{bwd:.2}"),
            format!("{opt:.2}"),
            format!("{retract:.2}"),
            format!("{step_ms:.2}"),
            format!("{tok_per_s:.0}"),
            format!("{retract_pct:.1}%"),
        ]);
        rows.push(json_obj![
            ("rank", rank),
            ("fwd_ms", fwd),
            ("bwd_ms", bwd),
            ("opt_ms", opt),
            ("retract_ms", retract),
            ("step_ms", step_ms),
            ("tok_per_s", tok_per_s),
            ("retract_pct", retract_pct),
        ]);
    }

    if let Some(path) = json_path {
        let doc = json_obj![
            ("bench", "train_step"),
            ("smoke", smoke),
            ("batch", w.batch),
            ("seq_len", w.seq_len),
            ("d_model", w.d_model),
            ("steps", w.steps),
            ("rows", rows),
        ];
        std::fs::write(&path, doc.to_string()).expect("writing bench JSON");
        println!("\nwrote {path}");
    }
}
