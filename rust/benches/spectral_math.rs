//! Micro-benchmarks of the native spectral substrate: QR retraction across
//! the paper's factor shapes, truncated SVD (the fine-tune conversion), and
//! the factored-vs-dense forward cost (the O(bk(m+n)) vs O(bmn) claim).
//!
//! These feed the §Perf iteration log in EXPERIMENTS.md — the QR retraction
//! is the paper's own named bottleneck ("40-50% of total step time", §5).
//!
//! Run: `cargo bench --bench spectral_math`

use sct::spectral::{qr_retract, svd_truncated, Matrix, SpectralLinear};
use sct::util::bench::Bench;
use sct::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(0);
    let mut b = Bench::new();

    // QR retraction at every Table 1 factor shape (k=32).
    println!("=== QR retraction (CGS2), paper factor shapes @ k=32 ===");
    for (name, m) in [
        ("smol135m_d", 576),
        ("smol1.7b_d", 2048),
        ("llama7b_f", 11008),
        ("llama70b_d", 8192),
        ("llama70b_f", 28672),
    ] {
        let a = Matrix::randn(&mut rng, m, 32, 1.0);
        b.run(&format!("qr_retract/{name}_{m}x32"), || {
            std::hint::black_box(qr_retract(&a));
        });
    }

    // Rank scaling at fixed m (the paper's O(mk^2) cost note).
    println!("\n=== QR retraction rank scaling (m=8192) ===");
    for k in [8usize, 32, 128] {
        let a = Matrix::randn(&mut rng, 8192, k, 1.0);
        b.run(&format!("qr_retract/m8192_k{k}"), || {
            std::hint::black_box(qr_retract(&a));
        });
    }

    // Truncated SVD at the fine-tune conversion shapes.
    println!("\n=== truncated SVD (Jacobi) — finetune conversion shapes ===");
    for (rows, cols) in [(64usize, 192usize), (128, 384)] {
        let w = Matrix::randn(&mut rng, rows, cols, 0.2);
        b.run(&format!("svd_truncated/{rows}x{cols}_k32"), || {
            std::hint::black_box(svd_truncated(&w, 32));
        });
    }

    // Factored vs dense forward: the FLOP-ratio claim behind Table 3's
    // step-time column.
    println!("\n=== forward: factored O(bk(m+n)) vs dense O(bmn) ===");
    let (batch, m, n, k) = (8, 2048, 8192, 32);
    let layer = SpectralLinear::init(&mut rng, m, n, k);
    let dense_w = layer.to_dense();
    let x = Matrix::randn(&mut rng, batch, m, 1.0);
    let sf = b.run("forward/factored_2048x8192_k32", || {
        std::hint::black_box(layer.forward(&x));
    });
    let factored_ns = sf.median();
    let sd = b.run("forward/dense_2048x8192", || {
        std::hint::black_box(x.matmul(&dense_w));
    });
    let dense_ns = sd.median();
    let speedup = dense_ns / factored_ns;
    let flop_ratio = (m * n) as f64 / (k * (m + n)) as f64;
    println!(
        "\nfactored forward is {speedup:.1}x faster (FLOP ratio predicts up to {flop_ratio:.0}x; \
         memory traffic caps it)"
    );
    assert!(speedup > 2.0, "factored forward must clearly beat dense at k=32");
}
