//! Retraction ablation — the paper's §5 future-work item, implemented.
//!
//! The paper: "QR retraction cost ... could become significant at higher
//! ranks or larger models. [It is] 40-50% of total step time [at 70B].
//! Cayley retraction is a potential lower-cost alternative."
//!
//! This bench compares, at the TRUE 70B factor shapes:
//! * serial CGS2 (the baseline implementation),
//! * blocked-parallel CGS2 (this repo's §Perf optimization),
//! * Newton-Schulz polar retraction (matmul-only — the MXU-friendly
//!   structure the paper's Cayley suggestion is after), at the
//!   near-manifold operating point retraction actually runs at (one AdamW
//!   step of drift), with the orthonormality each achieves.
//!
//! Run: `cargo bench --bench retraction_ablation`

use sct::spectral::{polar_retract, qr_retract_parallel, qr_retract_serial, Matrix};
use sct::util::bench::Bench;
use sct::util::rng::Rng;

fn perturbed_orthonormal(rng: &mut Rng, m: usize, k: usize, eps: f32) -> Matrix {
    let q = qr_retract_serial(&Matrix::randn(rng, m, k, 1.0));
    let mut a = q;
    for v in a.data.iter_mut() {
        *v += eps * rng.normal() as f32;
    }
    a
}

fn main() {
    let mut rng = Rng::new(7);
    let mut b = Bench::heavy();

    println!("=== retraction ablation at 70B factor shapes (near-manifold input) ===\n");
    for (label, m, k) in [
        ("70b_U_8192", 8192usize, 32usize),
        ("70b_V_28672", 28672, 32),
        ("70b_V_28672_k128", 28672, 128),
    ] {
        let a = perturbed_orthonormal(&mut rng, m, k, 5e-4);

        let s_serial = b.run(&format!("{label}/cgs2_serial"), || {
            std::hint::black_box(qr_retract_serial(&a));
        });
        let t_serial = s_serial.median();

        let s_par = b.run(&format!("{label}/cgs2_parallel"), || {
            std::hint::black_box(qr_retract_parallel(&a));
        });
        let t_par = s_par.median();

        let s_ns = b.run(&format!("{label}/polar_ns4"), || {
            std::hint::black_box(polar_retract(&a, 4));
        });
        let t_ns = s_ns.median();

        let e_serial = qr_retract_serial(&a).ortho_error();
        let e_par = qr_retract_parallel(&a).ortho_error();
        let e_ns = polar_retract(&a, 4).ortho_error();
        println!(
            "  {label}: parallel {:.1}x vs serial, NS4 {:.1}x vs serial; \
             ortho serial {e_serial:.1e} / parallel {e_par:.1e} / NS4 {e_ns:.1e}\n",
            t_serial / t_par,
            t_serial / t_ns,
        );
        assert!(e_par < 2e-6, "parallel CGS2 must meet the paper threshold");
        assert!(e_ns < 2e-6, "NS4 must meet the paper threshold near-manifold");
    }

    // What fraction of the paper's claim does this recover? The paper says
    // retraction was 40-50% of its step; a faster retraction moves the whole
    // step time.
    println!("(speedups feed EXPERIMENTS.md §Perf: retraction is the paper's named bottleneck)");
}
