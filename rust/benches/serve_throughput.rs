//! Serving throughput: batched (continuous batching, 8 slots) vs sequential
//! (1 slot) decode through the scheduler, at spectral ranks 32 and 128,
//! plus queue latency under concurrent load and the per-path token costs.
//!
//! The batched win comes from weight reuse: one `step_batch` over B rows
//! streams every projection matrix (and the logits head) once for B
//! sequences, where sequential decode re-streams them per sequence — on a
//! memory-bound CPU decode that is the whole game. The same workload runs
//! through both paths, so `speedup = sequential_wall / batched_wall`.
//!
//! Run: `cargo bench --bench serve_throughput`

use std::sync::Arc;
use std::time::Instant;

use sct::serve::{Batcher, Engine, EngineConfig, Request, SampleOpts, SpectralModel};
use sct::util::bench::{table_header, table_row};

const REQUESTS: usize = 8;
const TOKENS_PER_REQUEST: usize = 24;
const SLOTS_BATCHED: usize = 8;

fn bench_cfg(rank: usize) -> EngineConfig {
    EngineConfig {
        vocab: 256,
        d_model: 256,
        n_layers: 2,
        n_heads: 8,
        d_ffn: 512,
        rank,
        max_seq: 96,
    }
}

/// Push the standard workload through a batcher with `slots` decode slots;
/// returns (wall seconds, mean queue ms, mean decode ms).
fn run_workload(cfg: EngineConfig, slots: usize) -> (f64, f64, f64) {
    let engine = Engine::new(SpectralModel::init(cfg, 0));
    let batcher = Arc::new(Batcher::spawn(engine, slots, REQUESTS * 2));
    let t0 = Instant::now();
    let handles: Vec<_> = (0..REQUESTS)
        .map(|i| {
            let b = batcher.clone();
            std::thread::spawn(move || {
                b.generate(Request {
                    prompt: vec![(i as i32) + 1, 17, 42, 5],
                    max_new: TOKENS_PER_REQUEST,
                    opts: SampleOpts { temperature: 0.0, top_k: 0, seed: 0 },
                })
                .unwrap()
            })
        })
        .collect();
    let completions: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let wall = t0.elapsed().as_secs_f64();
    for c in &completions {
        assert_eq!(c.tokens.len(), TOKENS_PER_REQUEST);
    }
    let n = completions.len() as f64;
    let queue_ms = completions.iter().map(|c| c.queue_ms).sum::<f64>() / n;
    let decode_ms = completions.iter().map(|c| c.decode_ms).sum::<f64>() / n;
    (wall, queue_ms, decode_ms)
}

fn main() {
    println!(
        "serve throughput: {REQUESTS} requests x {TOKENS_PER_REQUEST} tokens, \
         d_model=256, 2 layers (sequential = 1 slot, batched = {SLOTS_BATCHED} slots)"
    );
    let total_tokens = (REQUESTS * TOKENS_PER_REQUEST) as f64;

    table_header(
        "Batched vs sequential serving",
        &["rank", "mode", "wall s", "tok/s", "mean queue ms", "mean decode ms", "speedup"],
    );
    for rank in [32usize, 128] {
        // warmup: one small run per engine shape so first-touch page faults
        // do not land in the sequential column.
        let _ = run_workload(bench_cfg(rank), 1);

        let (seq_wall, seq_q, seq_d) = run_workload(bench_cfg(rank), 1);
        let (bat_wall, bat_q, bat_d) = run_workload(bench_cfg(rank), SLOTS_BATCHED);
        let speedup = seq_wall / bat_wall;
        table_row(&[
            format!("{rank}"),
            "sequential".into(),
            format!("{seq_wall:.3}"),
            format!("{:.0}", total_tokens / seq_wall),
            format!("{seq_q:.1}"),
            format!("{seq_d:.1}"),
            "1.00x".into(),
        ]);
        table_row(&[
            format!("{rank}"),
            "batched".into(),
            format!("{bat_wall:.3}"),
            format!("{:.0}", total_tokens / bat_wall),
            format!("{bat_q:.1}"),
            format!("{bat_d:.1}"),
            format!("{speedup:.2}x"),
        ]);
        println!(
            "rank {rank}: continuous batching speedup {speedup:.2}x \
             (sequential queues requests behind one slot: mean wait {seq_q:.0} ms vs {bat_q:.0} ms batched)"
        );
    }
}
