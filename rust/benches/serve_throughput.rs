//! Serving latency + throughput: batched (continuous batching) vs
//! sequential (1 slot) decode through the scheduler, with client-observed
//! time-to-first-token (TTFT) and inter-token-latency (ITL) percentiles
//! measured off the streaming channel, plus a gateway worker ladder
//! (the same batched workload across N engine-clone schedulers —
//! `--workers N`, default 2 — with per-worker token splits and a T=0
//! identical-output assertion) and a chunked-prefill interleave probe
//! (does a 512-token prompt admission stall an active decode?).
//!
//! The batched win comes from weight reuse: one `step_batch` over B rows
//! streams every projection matrix (and the logits head) once for B
//! sequences, where sequential decode re-streams them per sequence — on a
//! memory-bound CPU decode that is the whole game. The same workload runs
//! through both paths, so `speedup = sequential_wall / batched_wall`.
//! TTFT/ITL come from per-token `StreamEvent` arrival times, i.e. exactly
//! what an SSE client observes minus the socket.
//!
//! Run: `cargo bench --bench serve_throughput`
//! Flags: `--smoke` (tiny model, few requests — the CI mode; also enabled
//! by the `SCT_BENCH_SMOKE` env var), `--json PATH` (write the numbers
//! as one JSON document, e.g. `BENCH_serve.json`, so CI can archive the
//! perf trajectory per PR), `--trace-out PATH` (record one span per
//! benchmark request, the `traces.jsonl` CI artifact), and
//! `--metrics-dump PATH` (scrape `GET /metrics` from a live server after
//! the workloads and save the exposition text, so CI can assert the
//! mandatory series exist).

use std::sync::mpsc::RecvTimeoutError;
use std::sync::Arc;
use std::time::{Duration, Instant};

use sct::json_obj;
use sct::obs::trace;
use sct::serve::{
    http_get_text, http_post_json, BatchConfig, Batcher, Completion, Engine, EngineConfig,
    Gateway, GatewayConfig, Request, SampleOpts, ServeConfig, Server, SpectralModel, StreamEvent,
};
use sct::util::bench::{table_header, table_row};
use sct::util::json::Json;

/// One benchmark scale (the smoke variant keeps CI under a few seconds).
#[derive(Clone, Copy)]
struct Workload {
    requests: usize,
    tokens_per_request: usize,
    slots_batched: usize,
    d_model: usize,
    d_ffn: usize,
    n_heads: usize,
    max_seq: usize,
    ranks: &'static [usize],
    /// Prefill-probe sizing: the long prompt admitted mid-decode and the
    /// active sequence's generation budget.
    long_prompt: usize,
    active_tokens: usize,
    prefill_chunk: usize,
}

const FULL: Workload = Workload {
    requests: 8,
    tokens_per_request: 24,
    slots_batched: 8,
    d_model: 256,
    d_ffn: 512,
    n_heads: 8,
    max_seq: 96,
    ranks: &[32, 128],
    long_prompt: 512,
    active_tokens: 64,
    prefill_chunk: 64,
};

const SMOKE: Workload = Workload {
    requests: 4,
    tokens_per_request: 8,
    slots_batched: 4,
    d_model: 64,
    d_ffn: 128,
    n_heads: 4,
    max_seq: 48,
    ranks: &[8],
    long_prompt: 96,
    active_tokens: 24,
    prefill_chunk: 16,
};

fn bench_cfg(w: &Workload, rank: usize) -> EngineConfig {
    EngineConfig {
        vocab: 256,
        d_model: w.d_model,
        n_layers: 2,
        n_heads: w.n_heads,
        d_ffn: w.d_ffn,
        rank,
        max_seq: w.max_seq,
        tied: true,
    }
}

fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    s[((s.len() as f64 - 1.0) * p).round() as usize]
}

struct WorkloadResult {
    wall_s: f64,
    ttft_ms: Vec<f64>,
    itl_ms: Vec<f64>,
    queue_ms_mean: f64,
    decode_ms_mean: f64,
}

/// Push the standard workload through a batcher with `slots` decode slots,
/// streaming every request so TTFT/ITL are measured at token granularity.
fn run_workload(
    cfg: EngineConfig,
    slots: usize,
    prefill_chunk: usize,
    requests: usize,
    tokens: usize,
) -> WorkloadResult {
    let engine = Engine::new(SpectralModel::init(cfg, 0));
    let batcher = Arc::new(Batcher::spawn_with(
        engine,
        BatchConfig { slots, queue_depth: requests * 2, prefill_chunk, ..BatchConfig::default() },
    ));
    let t0 = Instant::now();
    let handles: Vec<_> = (0..requests)
        .map(|i| {
            let b = batcher.clone();
            std::thread::spawn(move || {
                let sent = Instant::now();
                let rx = b
                    .submit_streaming(Request {
                        prompt: vec![(i as i32) + 1, 17, 42, 5],
                        max_new: tokens,
                        opts: SampleOpts { temperature: 0.0, top_k: 0, seed: 0 },
                        stop: vec![],
                    })
                    .unwrap();
                let mut ttft = None;
                let mut prev: Option<f64> = None;
                let mut itl = Vec::new();
                let mut done: Option<Completion> = None;
                for ev in rx {
                    match ev {
                        StreamEvent::Token(_) => {
                            let at = sent.elapsed().as_secs_f64() * 1e3;
                            if ttft.is_none() {
                                ttft = Some(at);
                            }
                            if let Some(p) = prev {
                                itl.push(at - p);
                            }
                            prev = Some(at);
                        }
                        StreamEvent::Done(c) => done = Some(c),
                    }
                }
                let c = done.expect("stream must terminate with Done");
                assert_eq!(c.tokens.len(), tokens);
                (ttft.expect("at least one token"), itl, c)
            })
        })
        .collect();
    let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let wall_s = t0.elapsed().as_secs_f64();
    let n = results.len() as f64;
    WorkloadResult {
        wall_s,
        ttft_ms: results.iter().map(|r| r.0).collect(),
        itl_ms: results.iter().flat_map(|r| r.1.iter().copied()).collect(),
        queue_ms_mean: results.iter().map(|r| r.2.queue_ms).sum::<f64>() / n,
        decode_ms_mean: results.iter().map(|r| r.2.decode_ms).sum::<f64>() / n,
    }
}

struct GatewayResult {
    workers: usize,
    wall_s: f64,
    tok_per_s: f64,
    /// Decoded token ids per request index — identical across worker counts
    /// at T=0 (the gateway's determinism contract).
    outputs: Vec<Vec<i32>>,
    /// `tokens_out` per worker, by worker index (placement spread).
    per_worker_tokens: Vec<u64>,
}

/// Push the batched workload through a `workers`-wide gateway with blocking
/// clients: aggregate decode throughput plus the per-worker token split.
/// This is the ladder behind the `--workers` acceptance number — on a
/// multi-core box two engine clones decode truly concurrently, so aggregate
/// tok/s should scale well past one scheduler's.
fn run_gateway_workload(
    cfg: EngineConfig,
    workers: usize,
    slots: usize,
    prefill_chunk: usize,
    requests: usize,
    tokens: usize,
) -> GatewayResult {
    let gw = Arc::new(Gateway::start(
        Engine::new(SpectralModel::init(cfg, 0)),
        &GatewayConfig {
            workers,
            batch: BatchConfig {
                slots,
                queue_depth: requests * 2,
                prefill_chunk,
                ..BatchConfig::default()
            },
        },
    ));
    let t0 = Instant::now();
    let handles: Vec<_> = (0..requests)
        .map(|i| {
            let g = gw.clone();
            std::thread::spawn(move || {
                let (_worker, c) = g
                    .generate(Request {
                        prompt: vec![(i as i32) + 1, 17, 42, 5],
                        max_new: tokens,
                        opts: SampleOpts { temperature: 0.0, top_k: 0, seed: 0 },
                        stop: vec![],
                    })
                    .expect("gateway generate");
                assert_eq!(c.tokens.len(), tokens);
                (i, c.tokens)
            })
        })
        .collect();
    let mut outputs = vec![Vec::new(); requests];
    for h in handles {
        let (i, toks) = h.join().unwrap();
        outputs[i] = toks;
    }
    let wall_s = t0.elapsed().as_secs_f64();
    GatewayResult {
        workers,
        wall_s,
        tok_per_s: (requests * tokens) as f64 / wall_s,
        outputs,
        per_worker_tokens: gw.worker_stats().iter().map(|s| s.tokens_out).collect(),
    }
}

struct ProbeResult {
    prefill_chunk: usize,
    b_ttft_ms: f64,
    active_max_gap_ms: f64,
    interleaved_tokens: usize,
}

/// Admit a `long_prompt`-token request while a short-prompt sequence is
/// actively decoding; measure the long request's TTFT, the worst stall the
/// active sequence experienced, and how many tokens it managed to produce
/// during admission. `prefill_chunk = 0` reproduces the pre-chunking inline
/// prefill (the stall this subsystem removes) for an A/B trajectory in CI.
fn prefill_probe(
    cfg: EngineConfig,
    prefill_chunk: usize,
    long_prompt: usize,
    active_tokens: usize,
) -> ProbeResult {
    let engine = Engine::new(SpectralModel::init(cfg, 0));
    let b = Batcher::spawn_with(
        engine,
        BatchConfig { slots: 2, queue_depth: 4, prefill_chunk, ..BatchConfig::default() },
    );
    let greedy = SampleOpts { temperature: 0.0, top_k: 0, seed: 0 };
    let rxa = b
        .submit_streaming(Request {
            prompt: vec![1, 2, 3],
            max_new: active_tokens,
            opts: greedy.clone(),
            stop: vec![],
        })
        .unwrap();
    match rxa.recv() {
        Ok(StreamEvent::Token(_)) => {} // the active sequence is decoding
        other => panic!("active sequence died early: {other:?}"),
    }

    let prompt: Vec<i32> = (0..long_prompt as i32).map(|i| (i % 251) + 1).collect();
    let t_b = Instant::now();
    let rxb =
        b.submit_streaming(Request { prompt, max_new: 4, opts: greedy, stop: vec![] }).unwrap();
    let mut last_a = Instant::now();
    let mut max_gap_ms = 0.0f64;
    let mut interleaved = 0usize;
    let mut a_open = true;
    let b_ttft_ms = loop {
        match rxb.try_recv() {
            Ok(StreamEvent::Token(_)) | Ok(StreamEvent::Done(_)) => {
                break t_b.elapsed().as_secs_f64() * 1e3;
            }
            Err(_) => {}
        }
        if a_open {
            match rxa.recv_timeout(Duration::from_millis(10)) {
                Ok(StreamEvent::Token(_)) => {
                    max_gap_ms = max_gap_ms.max(last_a.elapsed().as_secs_f64() * 1e3);
                    last_a = Instant::now();
                    interleaved += 1;
                }
                Ok(StreamEvent::Done(_)) | Err(RecvTimeoutError::Disconnected) => a_open = false,
                Err(RecvTimeoutError::Timeout) => {}
            }
        } else {
            match rxb.recv_timeout(Duration::from_secs(60)) {
                Ok(_) => break t_b.elapsed().as_secs_f64() * 1e3,
                Err(e) => panic!("long-prompt request stalled: {e:?}"),
            }
        }
    };
    // the stall the active sequence is in when B's first token lands counts
    max_gap_ms = max_gap_ms.max(last_a.elapsed().as_secs_f64() * 1e3);
    drop(rxa);
    drop(rxb);
    ProbeResult {
        prefill_chunk,
        b_ttft_ms,
        active_max_gap_ms: max_gap_ms,
        interleaved_tokens: interleaved,
    }
}

fn probe_json(p: &ProbeResult) -> Json {
    json_obj![
        ("prefill_chunk", p.prefill_chunk),
        ("b_ttft_ms", p.b_ttft_ms),
        ("active_max_gap_ms", p.active_max_gap_ms),
        ("interleaved_tokens", p.interleaved_tokens),
    ]
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let smoke = argv.iter().any(|a| a == "--smoke") || std::env::var("SCT_BENCH_SMOKE").is_ok();
    let json_path =
        argv.iter().position(|a| a == "--json").and_then(|i| argv.get(i + 1).cloned());
    let trace_path =
        argv.iter().position(|a| a == "--trace-out").and_then(|i| argv.get(i + 1).cloned());
    let metrics_path =
        argv.iter().position(|a| a == "--metrics-dump").and_then(|i| argv.get(i + 1).cloned());
    let workers_flag: usize = argv
        .iter()
        .position(|a| a == "--workers")
        .and_then(|i| argv.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(2)
        .max(1);
    if let Some(p) = &trace_path {
        trace::install_file(std::path::Path::new(p)).expect("installing trace sink");
    }
    let w = if smoke { SMOKE } else { FULL };
    let total_tokens = (w.requests * w.tokens_per_request) as f64;

    println!(
        "serve throughput{}: {} requests x {} tokens, d_model={}, 2 layers \
         (sequential = 1 slot, batched = {} slots, prefill_chunk = {})",
        if smoke { " [smoke]" } else { "" },
        w.requests,
        w.tokens_per_request,
        w.d_model,
        w.slots_batched,
        w.prefill_chunk,
    );

    table_header(
        "Batched vs sequential serving",
        &["rank", "mode", "wall s", "tok/s", "ttft p50/p95 ms", "itl p50/p95 ms", "speedup"],
    );
    let mut rows: Vec<Json> = Vec::new();
    for &rank in w.ranks {
        // warmup: one small run per engine shape so first-touch page faults
        // do not land in the sequential column.
        let _ =
            run_workload(bench_cfg(&w, rank), 1, w.prefill_chunk, w.requests, w.tokens_per_request);

        let modes = [("sequential", 1), ("batched", w.slots_batched)];
        let mut seq_wall = 0.0f64;
        for (mode, slots) in modes {
            let r = run_workload(
                bench_cfg(&w, rank),
                slots,
                w.prefill_chunk,
                w.requests,
                w.tokens_per_request,
            );
            if mode == "sequential" {
                seq_wall = r.wall_s;
            }
            let speedup = seq_wall / r.wall_s;
            let tok_per_s = total_tokens / r.wall_s;
            let (ttft50, ttft95) = (percentile(&r.ttft_ms, 0.50), percentile(&r.ttft_ms, 0.95));
            let (itl50, itl95) = (percentile(&r.itl_ms, 0.50), percentile(&r.itl_ms, 0.95));
            table_row(&[
                format!("{rank}"),
                mode.into(),
                format!("{:.3}", r.wall_s),
                format!("{tok_per_s:.0}"),
                format!("{ttft50:.1} / {ttft95:.1}"),
                format!("{itl50:.2} / {itl95:.2}"),
                format!("{speedup:.2}x"),
            ]);
            rows.push(json_obj![
                ("rank", rank),
                ("mode", mode),
                ("wall_s", r.wall_s),
                ("tok_per_s", tok_per_s),
                ("ttft_ms_p50", ttft50),
                ("ttft_ms_p95", ttft95),
                ("itl_ms_p50", itl50),
                ("itl_ms_p95", itl95),
                ("queue_ms_mean", r.queue_ms_mean),
                ("decode_ms_mean", r.decode_ms_mean),
                ("speedup", speedup),
            ]);
        }
    }

    // -- gateway worker ladder -----------------------------------------------
    // Same batched workload, now placed across N worker schedulers. The
    // workers=1 rung is the pre-gateway baseline; T=0 outputs must be
    // identical on every rung regardless of placement.
    let ladder: Vec<usize> =
        if workers_flag == 1 { vec![1] } else { vec![1, workers_flag] };
    table_header(
        "Gateway scaling (batched workload)",
        &["workers", "wall s", "tok/s", "per-worker tokens", "speedup vs 1"],
    );
    let mut gateway_rows: Vec<Json> = Vec::new();
    let mut base: Option<GatewayResult> = None;
    for &n in &ladder {
        let r = run_gateway_workload(
            bench_cfg(&w, w.ranks[0]),
            n,
            w.slots_batched,
            w.prefill_chunk,
            w.requests,
            w.tokens_per_request,
        );
        if let Some(b) = &base {
            assert_eq!(
                r.outputs, b.outputs,
                "T=0 outputs must be identical at any worker count"
            );
        }
        let speedup = base.as_ref().map(|b| b.wall_s / r.wall_s).unwrap_or(1.0);
        table_row(&[
            format!("{n}"),
            format!("{:.3}", r.wall_s),
            format!("{:.0}", r.tok_per_s),
            format!("{:?}", r.per_worker_tokens),
            format!("{speedup:.2}x"),
        ]);
        let per_worker: Vec<Json> = r
            .per_worker_tokens
            .iter()
            .enumerate()
            .map(|(i, &t)| {
                json_obj![
                    ("worker", i),
                    ("tokens_out", t as i64),
                    ("tok_per_s", t as f64 / r.wall_s),
                ]
            })
            .collect();
        gateway_rows.push(json_obj![
            ("workers", n),
            ("rank", w.ranks[0]),
            ("wall_s", r.wall_s),
            ("tok_per_s", r.tok_per_s),
            ("speedup_vs_1", speedup),
            ("t0_identical_to_baseline", true),
            ("per_worker", per_worker),
        ]);
        if base.is_none() {
            base = Some(r);
        }
    }

    // -- chunked-prefill interleave probe ------------------------------------
    let probe_cfg = EngineConfig {
        max_seq: w.long_prompt + 2 * w.active_tokens,
        ..bench_cfg(&w, w.ranks[0])
    };
    let chunked = prefill_probe(probe_cfg, w.prefill_chunk, w.long_prompt, w.active_tokens);
    let inline = prefill_probe(probe_cfg, 0, w.long_prompt, w.active_tokens);
    println!(
        "\nprefill interleave ({}-token prompt admitted mid-decode, rank {}):",
        w.long_prompt, w.ranks[0]
    );
    for p in [&chunked, &inline] {
        println!(
            "  prefill_chunk {:>3}: long-prompt TTFT {:>8.1} ms, active-seq worst stall \
             {:>8.1} ms, {} tokens interleaved",
            p.prefill_chunk, p.b_ttft_ms, p.active_max_gap_ms, p.interleaved_tokens
        );
    }
    println!(
        "  chunking cuts the active sequence's worst stall {:.1}x",
        inline.active_max_gap_ms / chunked.active_max_gap_ms.max(1e-6)
    );

    if let Some(path) = json_path {
        let doc = json_obj![
            ("bench", "serve_throughput"),
            ("smoke", smoke),
            ("requests", w.requests),
            ("tokens_per_request", w.tokens_per_request),
            ("d_model", w.d_model),
            ("rows", rows),
            ("gateway", json_obj![("workers_flag", workers_flag), ("rows", gateway_rows)]),
            (
                "prefill_probe",
                json_obj![
                    ("long_prompt", w.long_prompt),
                    ("active_tokens", w.active_tokens),
                    ("chunked", probe_json(&chunked)),
                    ("inline", probe_json(&inline)),
                ]
            ),
        ];
        std::fs::write(&path, doc.to_string()).expect("writing bench JSON");
        println!("\nwrote {path}");
    }

    if let Some(path) = metrics_path {
        // Scrape a live server rather than rendering the registry directly:
        // the dump then also covers the HTTP route counters and proves the
        // /metrics endpoint works end to end. The registry is process-global,
        // so every series the workloads above populated is in the scrape.
        let cfg = bench_cfg(&w, w.ranks[0]);
        let tokenizer = sct::data::tokenizer_for(cfg.vocab, 0);
        // workers matches the ladder so the scrape carries a worker="i"
        // label set per gateway worker.
        let server = Server::start(
            &ServeConfig {
                addr: "127.0.0.1:0".into(),
                workers: workers_flag,
                ..ServeConfig::default()
            },
            Engine::new(SpectralModel::init(cfg, 0)),
            tokenizer,
        )
        .expect("starting scrape server");
        let req = r#"{"prompt": "metrics scrape probe", "tokens": 4, "temperature": 0}"#;
        let (code, _) = http_post_json(server.addr, "/v1/generate", req).expect("generate");
        assert_eq!(code, 200, "scrape-probe generate must succeed");
        let (code, text) = http_get_text(server.addr, "/metrics").expect("GET /metrics");
        assert_eq!(code, 200, "/metrics must answer 200");
        server.stop();
        std::fs::write(&path, text).expect("writing metrics dump");
        println!("wrote {path}");
    }
    if let Some(p) = &trace_path {
        trace::uninstall();
        println!("wrote {p}");
    }
}
