//! Rank-transition benchmark: what a live grow/shrink costs, and how fast
//! training recovers after one.
//!
//! Two measurements per schedule milestone (the paper-sweep-inspired
//! 32 → 64 → 128 ladder in full mode):
//! * **resize latency** — wall time of `NativeTrainer::set_layer_rank` for
//!   a grow (orthonormal-complement column append + Adam moment resize)
//!   and for the matching shrink back, per layer;
//! * **steps-to-recover** — grow is an exact continuation (the loss at the
//!   transition step is unchanged — asserted here, not assumed), so
//!   "recovery" is measured as the number of steps until the training loss
//!   drops below the best loss seen before the transition, i.e. until the
//!   new capacity starts paying for itself.
//!
//! Run: `cargo bench --bench rank_transition`
//! Flags: `--smoke` (tiny shape — the CI mode; also via `SCT_BENCH_SMOKE`)
//! and `--json PATH` (write `BENCH_rank.json` for the CI trajectory diff).

use std::time::Instant;

use sct::json_obj;
use sct::serve::EngineConfig;
use sct::train::{NativeTrainConfig, NativeTrainer};
use sct::util::bench::{table_header, table_row};
use sct::util::json::Json;
use sct::util::rng::Rng;

#[derive(Clone, Copy)]
struct Workload {
    /// Rank ladder: train at ranks[0], grow to ranks[1], ... each for
    /// `steps_per_stage` steps.
    ranks: &'static [usize],
    d_model: usize,
    d_ffn: usize,
    n_heads: usize,
    batch: usize,
    seq_len: usize,
    steps_per_stage: usize,
    /// Timed resize repetitions per milestone.
    resize_reps: usize,
}

const FULL: Workload = Workload {
    ranks: &[32, 64, 128],
    d_model: 256,
    d_ffn: 512,
    n_heads: 8,
    batch: 4,
    seq_len: 32,
    steps_per_stage: 12,
    resize_reps: 8,
};

const SMOKE: Workload = Workload {
    ranks: &[4, 8, 12],
    d_model: 64,
    d_ffn: 128,
    n_heads: 4,
    batch: 2,
    seq_len: 16,
    steps_per_stage: 4,
    resize_reps: 3,
};

fn median_ms(samples: &mut [f64]) -> f64 {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[samples.len() / 2]
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let smoke = argv.iter().any(|a| a == "--smoke") || std::env::var("SCT_BENCH_SMOKE").is_ok();
    let json_path =
        argv.iter().position(|a| a == "--json").and_then(|i| argv.get(i + 1).cloned());
    let w = if smoke { SMOKE } else { FULL };

    println!(
        "rank transitions{}: d_model={}, d_ffn={}, 2 layers, ladder {:?}, {} steps/stage",
        if smoke { " [smoke]" } else { "" },
        w.d_model,
        w.d_ffn,
        w.ranks,
        w.steps_per_stage,
    );

    let cfg = NativeTrainConfig {
        model: EngineConfig {
            vocab: 256,
            d_model: w.d_model,
            n_layers: 2,
            n_heads: w.n_heads,
            d_ffn: w.d_ffn,
            rank: w.ranks[0],
            max_seq: w.seq_len.max(2),
            tied: true,
        },
        batch: w.batch,
        seq_len: w.seq_len,
        grad_clip: 1.0,
        retract_every: 1,
        weight_decay: 0.0,
    };

    // -- resize latency: repeated grow/shrink on a throwaway trainer --------
    table_header(
        "Resize latency (per layer, gate+up+down + Adam moments)",
        &["transition", "grow ms", "shrink ms"],
    );
    let mut latency_rows: Vec<Json> = Vec::new();
    for pair in w.ranks.windows(2) {
        let (from, to) = (pair[0], pair[1]);
        let mut trainer = NativeTrainer::new(cfg, 0);
        let mut rng = Rng::new(42);
        trainer.set_layer_rank(0, from, &mut rng).expect("seed rank");
        let (mut grow_ms, mut shrink_ms) = (Vec::new(), Vec::new());
        for _ in 0..w.resize_reps {
            let t0 = Instant::now();
            trainer.set_layer_rank(0, to, &mut rng).expect("grow");
            grow_ms.push(t0.elapsed().as_secs_f64() * 1e3);
            let t1 = Instant::now();
            trainer.set_layer_rank(0, from, &mut rng).expect("shrink");
            shrink_ms.push(t1.elapsed().as_secs_f64() * 1e3);
        }
        let (g, s) = (median_ms(&mut grow_ms), median_ms(&mut shrink_ms));
        table_row(&[format!("{from}->{to}"), format!("{g:.3}"), format!("{s:.3}")]);
        latency_rows.push(json_obj![
            ("from", from),
            ("to", to),
            ("grow_ms", g),
            ("shrink_ms", s),
        ]);
    }

    // -- steps-to-recover across the ladder ---------------------------------
    table_header(
        "Grow continuity + recovery across the ladder",
        &["transition", "loss before", "|delta| at transition", "steps to recover"],
    );
    let mut trainer = NativeTrainer::new(cfg, 1);
    let mut rng = Rng::new(7);
    let window = w.batch * (w.seq_len + 1);
    // deterministic learnable stream: token = (step + row*3 + col) % 16
    let mut step_no = 0usize;
    let mut batch = move || -> Vec<i32> {
        step_no += 1;
        (0..window)
            .map(|i| {
                let (row, col) = (i / (w.seq_len + 1), i % (w.seq_len + 1));
                ((step_no + row * 3 + col) % 16) as i32
            })
            .collect()
    };
    let mut recovery_rows: Vec<Json> = Vec::new();
    let mut best = f32::INFINITY;
    for _ in 0..w.steps_per_stage {
        let (l, _) = trainer.train_step(&batch(), 3e-3, 3e-3);
        best = best.min(l);
    }
    for &to in &w.ranks[1..] {
        let from = trainer.layer_ranks()[0];
        let probe = batch();
        let before = trainer.eval_loss(&probe);
        for layer in 0..2 {
            trainer.set_layer_rank(layer, to, &mut rng).expect("ladder grow");
        }
        let after = trainer.eval_loss(&probe);
        let delta = (after - before).abs();
        assert!(delta <= 1e-5, "grow must be loss-continuous (delta {delta})");
        let mut recover_steps = 0usize;
        let mut recovered = false;
        for s in 0..w.steps_per_stage {
            let (l, _) = trainer.train_step(&batch(), 3e-3, 3e-3);
            if !recovered && l < best {
                recover_steps = s + 1;
                recovered = true;
            }
            best = best.min(l);
        }
        let recover_str = if recovered {
            format!("{recover_steps}")
        } else {
            format!(">{}", w.steps_per_stage)
        };
        table_row(&[
            format!("{from}->{to}"),
            format!("{before:.4}"),
            format!("{delta:.1e}"),
            recover_str,
        ]);
        recovery_rows.push(json_obj![
            ("from", from),
            ("to", to),
            ("loss_before", before as f64),
            ("transition_delta", delta as f64),
            ("recovered", recovered),
            ("steps_to_recover", recover_steps),
        ]);
    }

    if let Some(path) = json_path {
        let doc = json_obj![
            ("bench", "rank_transition"),
            ("smoke", smoke),
            ("d_model", w.d_model),
            ("d_ffn", w.d_ffn),
            ("ladder", w.ranks.to_vec()),
            ("steps_per_stage", w.steps_per_stage),
            ("resize_latency", latency_rows),
            ("recovery", recovery_rows),
        ];
        std::fs::write(&path, doc.to_string()).expect("writing bench JSON");
        println!("\nwrote {path}");
    }
}
