//! API-compatible **stub** of the `xla` PJRT bindings.
//!
//! The offline image that builds this repository does not ship the XLA
//! extension library, so the `pjrt` feature resolves this crate instead of
//! the real bindings. It mirrors exactly the API surface `sct::runtime`
//! uses — `PjRtClient`, `PjRtLoadedExecutable`, `Literal`, `HloModuleProto`,
//! `XlaComputation`, `ElementType` — with every entry point that would touch
//! the PJRT runtime returning a descriptive error at *runtime*. Code gated
//! behind `--features pjrt` therefore still type-checks and links; a full
//! environment swaps this path dependency for the real crate (same name,
//! same API) and nothing else changes.
//!
//! Unit tests that exercise real literals/executables are expected to fail
//! against this stub; they are only meaningful with the real bindings.

use std::borrow::Borrow;

/// Error type matching the real bindings' `anyhow`-compatible surface.
#[derive(Debug)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT runtime not linked (this is the offline API stub; \
         build against the real `xla` crate for execution)"
    )))
}

/// Element types the SCT artifacts use on the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    U32,
}

/// Host types a [`Literal`] can be read back into.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}
impl NativeType for u32 {}

/// Host-side tensor value (opaque in the stub).
#[derive(Debug)]
pub struct Literal(());

impl Literal {
    pub fn scalar<T: NativeType>(_v: T) -> Literal {
        Literal(())
    }

    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _shape: &[usize],
        _bytes: &[u8],
    ) -> Result<Literal> {
        unavailable("Literal::create_from_shape_and_untyped_data")
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn element_count(&self) -> usize {
        0
    }
}

/// Parsed HLO module (text interchange format).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// A computation ready for compilation.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device buffer handle returned by an execution.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// Process/thread-scoped PJRT client.
#[derive(Debug, Clone)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}
