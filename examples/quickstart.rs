//! Quickstart: the whole three-layer stack in ~40 lines of driver code.
//!
//! Loads the AOT artifacts (built once by `make artifacts`), initializes a
//! tiny SCT model, trains a few dozen steps on the synthetic instruction
//! corpus, and verifies the paper's core invariants: loss goes down, no
//! dense matrix ever exists, factors stay on the Stiefel manifold (< 2e-6).
//!
//! Run: `cargo run --release --example quickstart`

use sct::coordinator::{LrPlan, RunConfig, Trainer};
use sct::memmodel::report::render_table1;

fn main() -> anyhow::Result<()> {
    let mut cfg = RunConfig::default();
    cfg.preset = std::env::args().nth(1).unwrap_or_else(|| "tiny_r8".into());
    cfg.steps = 60;
    cfg.lr_plan = LrPlan::split(1e-3, 5e-3);
    cfg.eval_every = 20;
    cfg.ortho_every = 20;

    println!("== SCT quickstart: preset {} ==\n", cfg.preset);
    let mut trainer = Trainer::new(cfg)?;
    let m = &trainer.session.preset.model;
    println!(
        "model: d={} layers={} ffn={} vocab={} rank={:?} ({} params)",
        m.d_model, m.n_layers, m.d_ffn, m.vocab, m.rank, m.param_count
    );
    println!(
        "training state on the wire: {:.2} MB ({} tensors — factors only, no dense W)\n",
        trainer.session.preset.state_bytes() as f64 / 1e6,
        trainer.session.preset.n_state,
    );

    let summary = trainer.run()?;
    let losses = &summary.losses;
    println!("loss: {:.3} -> {:.3} over {} steps", losses[0], summary.final_loss_smoothed, summary.steps);
    println!("eval loss: {:?}", summary.eval_loss);
    println!(
        "orthonormality after training: {:.2e} (paper threshold 2e-6)",
        summary.ortho_error.unwrap_or(f32::NAN)
    );
    println!("mean step time: {:.1} ms\n", summary.mean_step_s * 1e3);

    anyhow::ensure!(
        summary.final_loss_smoothed < losses[0],
        "loss must decrease in the quickstart"
    );
    anyhow::ensure!(summary.ortho_error.unwrap_or(1.0) < 2e-6, "manifold must hold");

    println!("and the reason to care — the paper's Table 1 at real scales:\n");
    println!("{}", render_table1(32));
    println!("quickstart OK");
    Ok(())
}
