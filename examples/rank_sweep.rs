//! Rank-sweep experiment — reproduces Table 3, Figure 2 and Figure 3
//! (scaled testbed; see DESIGN.md §4 for the substitution).
//!
//! Protocol mirrors the paper's §4.2: a dense baseline at LR 2e-5 and SCT at
//! four ranks at LR 5e-4, same data/steps/seed, loss+PPL smoothed with
//! window 50. `--split-lr` additionally runs the paper's §5 "clear next
//! step" (dense-calibrated LR for attention/embeddings, hot LR for spectral
//! factors), which the paper names but does not run.
//!
//! Run: `cargo run --release --example rank_sweep -- [--steps N] [--split-lr]`

use sct::coordinator::sweep::{check_observations, paper_presets, render_fig2, render_fig3, render_table3, run_sweep};
use sct::coordinator::RunConfig;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut steps = 200usize;
    let mut split_lr = false;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--steps" => steps = it.next().and_then(|s| s.parse().ok()).unwrap_or(steps),
            "--split-lr" => split_lr = true,
            other => anyhow::bail!("unknown arg {other} (use --steps N / --split-lr)"),
        }
    }

    let mut cfg = RunConfig::default();
    cfg.steps = steps;
    cfg.corpus_bytes = 2 << 20;
    cfg.out_dir = "runs/sweep".into();

    println!(
        "== SCT rank sweep: dense + r∈{{8,16,32,64}}, {} steps each{} ==\n",
        steps,
        if split_lr { " (split LR)" } else { " (paper single-LR protocol)" }
    );
    let result = run_sweep(&cfg, &paper_presets(split_lr))?;

    // persist smoothed curves for EXPERIMENTS.md / offline plotting
    std::fs::create_dir_all(&cfg.out_dir)?;
    for (label, ys) in &result.curves {
        let mut t = sct::metrics::Tracker::new(1);
        for &y in ys {
            t.record(y, 0.0);
        }
        let path = std::path::PathBuf::from(&cfg.out_dir)
            .join(format!("sweep_{}.csv", label.replace([' ', '='], "_")));
        sct::metrics::export::write_loss_csv(&t, &path)?;
    }

    println!("{}", render_table3(&result.rows));
    println!("{}", render_fig2(&result.curves));
    println!("{}", render_fig3(&result.rows));

    println!("paper §4.3 observations, checked on this run:");
    let checks = check_observations(&result.rows);
    let mut deviations = 0;
    for (what, ok) in &checks {
        println!("  [{}] {what}", if *ok { "OK " } else { "DEVIATION" });
        deviations += usize::from(!ok);
    }
    if deviations > 0 {
        println!(
            "\n{deviations} deviation(s) — expected at short horizons / from-scratch \
             regime; see EXPERIMENTS.md for the recorded analysis"
        );
    }
    // Hard requirements regardless of horizon: SCT must undercut dense on
    // memory, and all runs must have learned something.
    let dense = result.rows.iter().find(|r| r.label == "Dense").unwrap();
    for r in &result.rows {
        anyhow::ensure!(r.loss.is_finite() && r.ppl.is_finite(), "{} diverged", r.label);
        if r.label != "Dense" {
            anyhow::ensure!(r.state_mb < dense.state_mb, "{} should use less memory", r.label);
            anyhow::ensure!(
                r.ortho.unwrap_or(1.0) < 2e-6,
                "{} violated the manifold",
                r.label
            );
        }
    }
    println!("\nrank sweep OK");
    Ok(())
}
