//! Fine-tuning gradient-integrity experiment — reproduces Table 4 (§4.4,
//! scaled).
//!
//! Dense pre-training -> truncated-SVD conversion at 95% energy retention
//! (rust Jacobi SVD + orthonormal rank padding) -> fine-tune the converted
//! and the dense model on the same held-out corpus with the same seed and
//! LR. The claim under test is gradient integrity through the spectral
//! parameterization: SCT must recover from the conversion loss spike and
//! land within a small factor of dense PPL (paper: 1.38x at 135M).
//!
//! Run: `cargo run --release --example finetune_integrity -- [--finetune-steps N]`

use sct::coordinator::finetune::{render_table4, run_finetune, FinetuneOpts};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut opts = FinetuneOpts::default();
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--pretrain-steps" => {
                opts.pretrain_steps = it.next().and_then(|s| s.parse().ok()).unwrap_or(opts.pretrain_steps)
            }
            "--finetune-steps" => {
                opts.finetune_steps = it.next().and_then(|s| s.parse().ok()).unwrap_or(opts.finetune_steps)
            }
            "--energy" => opts.energy = it.next().and_then(|s| s.parse().ok()).unwrap_or(opts.energy),
            "--seed" => opts.seed = it.next().and_then(|s| s.parse().ok()).unwrap_or(opts.seed),
            other => anyhow::bail!("unknown arg {other}"),
        }
    }

    println!(
        "== fine-tune gradient integrity: {} pretrain + {} finetune steps, {:.0}% energy ==\n",
        opts.pretrain_steps,
        opts.finetune_steps,
        opts.energy * 100.0
    );
    let result = run_finetune(&opts)?;
    println!("{}", render_table4(&result));

    let ratio = result.sct.ppl / result.dense.ppl;
    // The paper's quantitative claim at its scale is 1.38x; the qualitative
    // claim — SCT recovers to within a small factor — is what survives
    // scaling. Accept up to 2x.
    anyhow::ensure!(
        ratio < 2.0,
        "SCT should recover to within 2x of dense PPL, got {ratio:.2}x"
    );
    anyhow::ensure!(
        result.sct.final_loss < result.sct.initial_loss,
        "SCT must recover from the conversion spike"
    );
    println!("finetune_integrity OK (PPL ratio {ratio:.2}x; paper reports 1.38x at 135M)");
    Ok(())
}
