//! Serving demo: the whole `serve` subsystem end-to-end on one machine.
//!
//! Starts the spectral inference server on a loopback port with a tiny
//! random-init model (rank-8 spectral MLPs — no dense weight exists), fires
//! 12 concurrent HTTP generation requests at it, verifies every one
//! completes, checks that greedy requests are reproducible, then streams
//! the same prompt over SSE — printing time-to-first-token and the
//! inter-token latency spread, and verifying the streamed tokens equal the
//! one-shot response. Finishes with the correctness anchor: the KV-cached
//! decoder emits exactly the same tokens as the full re-encode baseline at
//! temperature 0.
//!
//! Run: `cargo run --release --example serve_demo`

use std::time::Instant;

use sct::data::Tokenizer;
use sct::serve::{
    http_post_json, http_post_sse, Engine, EngineConfig, SampleOpts, ServeConfig, Server,
    SpectralModel,
};
use sct::util::json::Json;

const CLIENTS: usize = 12;
const TOKENS_PER_REQUEST: usize = 24;

fn main() -> anyhow::Result<()> {
    let model_cfg = EngineConfig::default(); // the tiny_r8 testbed shape
    let model = SpectralModel::init(model_cfg, 7);
    println!("== SCT serve demo ==\n");
    println!(
        "model: d={} layers={} heads={} ffn={} vocab={} rank={} ({} params, factors only)",
        model_cfg.d_model,
        model_cfg.n_layers,
        model_cfg.n_heads,
        model_cfg.d_ffn,
        model_cfg.vocab,
        model_cfg.rank,
        model.param_count(),
    );

    let serve_cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        slots: 8,
        queue_depth: 32,
        max_new_default: TOKENS_PER_REQUEST,
        ..ServeConfig::default()
    };
    let server = Server::start(&serve_cfg, Engine::new(model), Tokenizer::byte_level())?;
    println!(
        "serving on http://{} with {} slots, queue depth {}\n",
        server.addr, serve_cfg.slots, serve_cfg.queue_depth
    );

    // -- 12 concurrent clients ---------------------------------------------
    let t0 = Instant::now();
    let addr = server.addr;
    let handles: Vec<_> = (0..CLIENTS)
        .map(|i| {
            std::thread::spawn(move || {
                // Two greedy clients share a prompt (reproducibility probe);
                // the rest sample with distinct seeds.
                let body = if i < 2 {
                    format!(
                        r#"{{"prompt": "### Instruction: explain truncated SVD", "tokens": {TOKENS_PER_REQUEST}, "temperature": 0}}"#
                    )
                } else {
                    format!(
                        r#"{{"prompt": "client {i} asks about Stiefel manifolds", "tokens": {TOKENS_PER_REQUEST}, "temperature": 0.8, "seed": {i}}}"#
                    )
                };
                http_post_json(addr, "/v1/generate", &body).expect("request failed")
            })
        })
        .collect();
    let responses: Vec<(u16, Json)> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    let wall = t0.elapsed().as_secs_f64();

    println!("{:<8} {:>8} {:>12} {:>12}", "client", "status", "queue ms", "decode ms");
    let mut total_tokens = 0usize;
    for (i, (code, body)) in responses.iter().enumerate() {
        anyhow::ensure!(*code == 200, "client {i} got HTTP {code}: {body:?}");
        let n = body.get("tokens").unwrap().as_arr()?.len();
        anyhow::ensure!(n == TOKENS_PER_REQUEST, "client {i}: {n} tokens");
        total_tokens += n;
        println!(
            "{i:<8} {code:>8} {:>12.2} {:>12.2}",
            body.get("queue_ms").unwrap().as_f64()?,
            body.get("decode_ms").unwrap().as_f64()?
        );
    }
    println!(
        "\nall {CLIENTS} concurrent requests completed: {total_tokens} tokens in {:.2}s ({:.0} tok/s aggregate)",
        wall,
        total_tokens as f64 / wall
    );

    // greedy reproducibility across requests
    let greedy_a = responses[0].1.get("tokens").unwrap().to_string();
    let greedy_b = responses[1].1.get("tokens").unwrap().to_string();
    anyhow::ensure!(greedy_a == greedy_b, "greedy requests with one prompt must agree");
    println!("greedy requests with identical prompts produced identical tokens");

    // -- streaming: the same greedy prompt over SSE --------------------------
    println!("\nstreaming the greedy prompt over SSE:");
    let (code, frames) = http_post_sse(
        addr,
        "/v1/generate",
        &format!(
            r#"{{"prompt": "### Instruction: explain truncated SVD", "tokens": {TOKENS_PER_REQUEST}, "temperature": 0, "stream": true}}"#
        ),
    )?;
    anyhow::ensure!(code == 200, "streaming request got HTTP {code}");
    anyhow::ensure!(
        frames.len() == TOKENS_PER_REQUEST + 1,
        "expected {TOKENS_PER_REQUEST} token frames + 1 usage frame, got {}",
        frames.len()
    );
    let streamed: Vec<i64> = frames[..TOKENS_PER_REQUEST]
        .iter()
        .map(|f| f.data.get("token").unwrap().as_i64())
        .collect::<anyhow::Result<_>>()?;
    let oneshot: Vec<i64> = responses[0]
        .1
        .get("tokens")
        .unwrap()
        .as_arr()?
        .iter()
        .map(|v| v.as_i64())
        .collect::<anyhow::Result<_>>()?;
    anyhow::ensure!(streamed == oneshot, "SSE tokens must equal the one-shot sequence");
    let ttft_ms = frames[0].at_s * 1e3;
    let itl_ms: Vec<f64> = frames[..TOKENS_PER_REQUEST]
        .windows(2)
        .map(|w| (w[1].at_s - w[0].at_s) * 1e3)
        .collect();
    let mean_itl = itl_ms.iter().sum::<f64>() / itl_ms.len().max(1) as f64;
    let max_itl = itl_ms.iter().fold(0.0f64, |a, &b| a.max(b));
    println!(
        "  {} frames, token-identical to the one-shot response; \
         TTFT {ttft_ms:.2} ms, inter-token latency mean {mean_itl:.2} ms / max {max_itl:.2} ms",
        frames.len()
    );
    let usage = &frames[TOKENS_PER_REQUEST].data;
    println!(
        "  final frame usage: ttft {:.2} ms, decode {:.2} ms, {:.0} tok/s",
        usage.get("ttft_ms").unwrap().as_f64()?,
        usage.get("decode_ms").unwrap().as_f64()?,
        usage.get("tok_per_s").unwrap().as_f64()?
    );

    let stats = server.stats();
    println!(
        "scheduler: admitted={} completed={} peak_active={} queue_depth={} active_slots={}",
        stats.admitted, stats.completed, stats.peak_active, stats.queue_depth, stats.active_slots
    );
    // 12 one-shot clients + the SSE streaming request above
    anyhow::ensure!(
        stats.completed == CLIENTS as u64 + 1,
        "scheduler must complete every request"
    );
    server.stop();

    // -- correctness anchor: KV decode == re-encode baseline ----------------
    println!("\nKV-cache equivalence check (temperature 0):");
    let engine = Engine::new(SpectralModel::init(EngineConfig::default(), 7));
    let prompt = Tokenizer::byte_level().encode("### Instruction: explain truncated SVD");
    let opts = SampleOpts { temperature: 0.0, top_k: 0, seed: 0 };
    let t_re = Instant::now();
    let baseline = engine.generate_reencode(&prompt, 32, &opts);
    let t_re = t_re.elapsed().as_secs_f64();
    let mut kv = engine.new_kv(1);
    let slot = kv.alloc().unwrap();
    let t_kv = Instant::now();
    let cached = engine.generate_kv(&prompt, 32, &opts, &mut kv, slot);
    let t_kv = t_kv.elapsed().as_secs_f64();
    anyhow::ensure!(baseline == cached, "KV decode diverged from the re-encode baseline");
    println!(
        "  token-identical over {} tokens; re-encode {:.1} ms vs KV {:.1} ms ({:.1}x)",
        baseline.len(),
        t_re * 1e3,
        t_kv * 1e3,
        t_re / t_kv.max(1e-9)
    );
    println!("\nserve demo OK");
    Ok(())
}
