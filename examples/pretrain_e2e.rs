//! End-to-end pre-training driver — the full-system workload.
//!
//! Trains the `e2e_r64` preset (a ~28M-parameter SmolLM2-family transformer
//! with spectral MLPs — the "100M-class" testbed scaled to what XLA-CPU
//! trains in minutes; DESIGN.md §4) for a few hundred steps on the synthetic
//! instruction corpus, exercising every layer of the stack: AOT artifacts,
//! PJRT runtime, fused train chunks, prefetching data pipeline, LR
//! schedules, checkpointing, metrics. Logs the loss curve (CSV + ASCII) and
//! throughput; results are recorded in EXPERIMENTS.md §E2E.
//!
//! Run: `cargo run --release --example pretrain_e2e -- [steps] [preset]`

use sct::coordinator::{LrPlan, RunConfig, Trainer};
use sct::coordinator::schedule::Schedule;
use sct::metrics::{export, plot};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    let preset = args.get(2).cloned().unwrap_or_else(|| "e2e_r64".into());

    let mut cfg = RunConfig::default();
    cfg.preset = preset.clone();
    cfg.steps = steps;
    cfg.corpus_bytes = 4 << 20;
    // Warmup-cosine on both groups; spectral factors run hotter (the
    // paper's §5 per-component proposal).
    cfg.lr_plan = LrPlan {
        dense: Schedule::WarmupCosine { peak: 3e-4, floor: 3e-5, warmup: 20, total: steps },
        spectral: Schedule::WarmupCosine { peak: 1.5e-3, floor: 1.5e-4, warmup: 20, total: steps },
    };
    cfg.eval_every = 50;
    cfg.ortho_every = 100;
    cfg.ckpt_dir = Some(format!("runs/{preset}_ckpt"));
    cfg.ckpt_every = 100;

    println!("== SCT end-to-end pre-training: {preset}, {steps} steps ==");
    let t_open = std::time::Instant::now();
    let mut trainer = Trainer::new(cfg)?;
    let m = trainer.session.preset.model.clone();
    let tokens_per_step = m.batch * m.seq_len;
    println!(
        "model: d={} L={} ffn={} vocab={} rank={:?} -> {:.1}M params; state {:.0} MB",
        m.d_model,
        m.n_layers,
        m.d_ffn,
        m.vocab,
        m.rank,
        m.param_count as f64 / 1e6,
        trainer.session.preset.state_bytes() as f64 / 1e6
    );

    let summary = trainer.run()?;
    let wall = t_open.elapsed().as_secs_f64();
    println!("\nfinished {} steps in {:.0}s (incl. XLA compile)", summary.steps, wall);
    for (name, secs) in &trainer.session.compile_times {
        println!("  compile {name}: {secs:.1}s");
    }
    println!(
        "loss {:.3} -> {:.3} (ppl {:.1}); eval {:?}; ortho {:?}",
        summary.losses[0],
        summary.final_loss_smoothed,
        summary.ppl,
        summary.eval_loss,
        summary.ortho_error
    );
    println!(
        "throughput: {:.0} tokens/s ({:.0} ms/step)",
        tokens_per_step as f64 / summary.mean_step_s,
        summary.mean_step_s * 1e3
    );

    // loss curve: CSV + ASCII
    std::fs::create_dir_all("runs")?;
    let csv = std::path::PathBuf::from(format!("runs/{preset}_e2e_loss.csv"));
    export::write_loss_csv(&trainer.tracker, &csv)?;
    println!("\nloss curve -> {}", csv.display());
    let series = vec![(preset.clone(), trainer.tracker.smoothed_series())];
    println!("{}", plot::line_plot(&series, 16, 70));

    anyhow::ensure!(
        summary.final_loss_smoothed < summary.losses[0] - 0.5,
        "e2e pre-training must make real progress (got {:.3} -> {:.3})",
        summary.losses[0],
        summary.final_loss_smoothed
    );
    println!("e2e pre-training OK");
    Ok(())
}
