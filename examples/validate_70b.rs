//! 70B architecture validation — reproduces Table 2 and Figure 1 (§4.1).
//!
//! Memory is analytic (the same arithmetic the paper uses — its own dense
//! 1,245 GB number is analytic); phase times are MEASURED at the true 70B
//! factor shapes (8192x28672 @ k=32) through the native rust SpectralLinear
//! — running a full forward/backward/AdamW/QR-retraction step at 70B shapes
//! on whatever machine this is, which is precisely the capability the paper
//! claims to unlock. Also prints Table 1 and the baseline-method comparison.
//!
//! Run: `cargo run --release --example validate_70b -- [--rank K] [--layers N]`

use sct::coordinator::validate70b::{measure_70b_phases, render_table2};
use sct::memmodel::report::{baseline_rows, render_table1};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut rank = 32usize;
    let mut layers = 2usize;
    let mut batch = 4usize;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--rank" => rank = it.next().and_then(|s| s.parse().ok()).unwrap_or(rank),
            "--layers" => layers = it.next().and_then(|s| s.parse().ok()).unwrap_or(layers),
            "--batch" => batch = it.next().and_then(|s| s.parse().ok()).unwrap_or(batch),
            other => anyhow::bail!("unknown arg {other}"),
        }
    }

    println!("== 70B validation: k={rank}, measuring {layers}/80 layers at true shapes ==\n");
    let phases = measure_70b_phases(rank, batch, layers)?;
    println!("{}", render_table2(rank, &phases));

    println!("{}", render_table1(rank));

    println!("70B MLP-stack training memory by method (GB):");
    for (name, gb) in baseline_rows(rank) {
        println!("  {name:<12} {gb:>10.1}");
    }

    // The paper's structural claim worth machine-checking: retraction is a
    // major phase cost (40-50% on their hardware).
    let frac = phases.retract_fraction();
    println!(
        "\nretraction share of total step: {:.0}% (paper: 40-50%)",
        frac * 100.0
    );
    anyhow::ensure!(
        phases.ortho_error < 2e-6,
        "orthonormality after a true-shape step must hold"
    );
    println!("validate_70b OK");
    Ok(())
}
